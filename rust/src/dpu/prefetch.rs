//! Pluggable prefetch subsystem for dynamic caching (§III-A, §IV-C).
//!
//! "Based on accesses to the DPU cache, the prefetcher loads adjacent data
//! chunks from the memory node and stages them on the DPU cache, which
//! occurs off the critical path. Moreover, the larger transfer size avoids
//! the overhead of several smaller transfers."
//!
//! The paper leaves the prefetch heuristic as one of SODA's "customizable
//! data caching and prefetching optimizations"; this module makes it a
//! runtime-selectable seam, mirroring the unified cache subsystem
//! ([`crate::cache`]): a [`PrefetchPolicy`] engine behind the
//! [`Prefetcher`] shell, chosen by [`PrefetchPolicyKind`].
//!
//! | kind         | plans                                                        |
//! |--------------|--------------------------------------------------------------|
//! | `off`        | nothing (prefetch disabled — the ablation baseline)          |
//! | `sequential` | accessed entry + `depth` adjacent entries (seed-identical)   |
//! | `strided`    | accessed entry + `depth` stride-predicted entries, falling back to adjacent until a constant page stride is confirmed twice |
//! | `graph-hint` | accessed entry + application frontier hints from the host→DPU hint channel ([`crate::fabric::protocol::HintMessage`]) |
//! | `adaptive`   | any engine above, throttled by prefetch accuracy and a net-traffic budget (`adaptive` = `adaptive:sequential`) |
//!
//! Every engine consumes the [`RecentList`] through a sequence cursor (the
//! condition-variable hand-off of the C++ implementation) and plans
//! whole-entry fetches, skipping entries already resident or in flight.
//! The `graph-hint` queue is fed by
//! [`DpuAgent::handle_hint`](crate::dpu::DpuAgent::handle_hint); the
//! adaptive throttle reads the
//! exact useful/wasted prefetch accounting the [`CacheTable`] keeps per
//! entry. Selection threads through `DpuConfig::prefetch.policy`,
//! `SodaConfig::prefetch.policy` and the CLI (`--prefetch-policy`).

use super::cache_table::{CacheTable, EntryKey, PrefetchOrigin};
use super::recent_list::RecentList;
use crate::memnode::RegionId;
use crate::util::fxhash::{FxHashMap, FxHashSet};
use std::collections::VecDeque;

/// The runtime-selectable prefetch engines.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PrefetchPolicyKind {
    /// No prefetching at all (the ablation baseline).
    Off,
    /// The paper's sequential-adjacent planner (byte-for-byte default).
    Sequential,
    /// Constant-stride detection over the recent list.
    Strided,
    /// Application-guided: frontier hints from the host→DPU hint channel.
    GraphHint,
    /// Accuracy-driven throttle wrapped around a base engine.
    Adaptive(AdaptiveBase),
}

/// Base engines the adaptive throttle can wrap.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AdaptiveBase {
    Sequential,
    Strided,
    GraphHint,
}

impl PrefetchPolicyKind {
    /// The headline policy set, in ablation-sweep order (`adaptive` is
    /// `adaptive:sequential`; the other wrapped forms parse but are not
    /// swept by default).
    pub const ALL: [PrefetchPolicyKind; 5] = [
        PrefetchPolicyKind::Off,
        PrefetchPolicyKind::Sequential,
        PrefetchPolicyKind::Strided,
        PrefetchPolicyKind::GraphHint,
        PrefetchPolicyKind::Adaptive(AdaptiveBase::Sequential),
    ];

    /// Canonical name (config JSON / CLI / figure labels).
    pub fn name(&self) -> &'static str {
        match self {
            PrefetchPolicyKind::Off => "off",
            PrefetchPolicyKind::Sequential => "sequential",
            PrefetchPolicyKind::Strided => "strided",
            PrefetchPolicyKind::GraphHint => "graph-hint",
            PrefetchPolicyKind::Adaptive(AdaptiveBase::Sequential) => "adaptive",
            PrefetchPolicyKind::Adaptive(AdaptiveBase::Strided) => "adaptive:strided",
            PrefetchPolicyKind::Adaptive(AdaptiveBase::GraphHint) => "adaptive:graph-hint",
        }
    }

    /// Parse a policy name (canonical names plus common aliases).
    pub fn parse(s: &str) -> Option<PrefetchPolicyKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" => Some(PrefetchPolicyKind::Off),
            "sequential" | "seq" => Some(PrefetchPolicyKind::Sequential),
            "strided" | "stride" => Some(PrefetchPolicyKind::Strided),
            "graph-hint" | "graph" | "hint" => Some(PrefetchPolicyKind::GraphHint),
            "adaptive" | "adaptive:sequential" => {
                Some(PrefetchPolicyKind::Adaptive(AdaptiveBase::Sequential))
            }
            "adaptive:strided" => Some(PrefetchPolicyKind::Adaptive(AdaptiveBase::Strided)),
            "adaptive:graph-hint" | "adaptive:graph" => {
                Some(PrefetchPolicyKind::Adaptive(AdaptiveBase::GraphHint))
            }
            _ => None,
        }
    }

    /// Does this policy consume frontier hints? (Gates the hint channel:
    /// hints are never sent toward a policy that ignores them.)
    pub fn wants_hints(&self) -> bool {
        matches!(
            self,
            PrefetchPolicyKind::GraphHint
                | PrefetchPolicyKind::Adaptive(AdaptiveBase::GraphHint)
        )
    }

    /// Build the policy engine.
    pub fn build(&self) -> Box<dyn PrefetchPolicy> {
        match self {
            PrefetchPolicyKind::Off => Box::new(OffPolicy::default()),
            PrefetchPolicyKind::Sequential => Box::new(SequentialPolicy::default()),
            PrefetchPolicyKind::Strided => Box::new(StridedPolicy::default()),
            PrefetchPolicyKind::GraphHint => Box::new(GraphHintPolicy::default()),
            PrefetchPolicyKind::Adaptive(base) => {
                let inner: Box<dyn PrefetchPolicy> = match base {
                    AdaptiveBase::Sequential => Box::new(SequentialPolicy::default()),
                    AdaptiveBase::Strided => Box::new(StridedPolicy::default()),
                    AdaptiveBase::GraphHint => Box::new(GraphHintPolicy::default()),
                };
                Box::new(AdaptivePolicy::new(*base, inner))
            }
        }
    }
}

/// Prefetcher configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefetchConfig {
    /// Adjacent/predicted entries to fetch ahead of each accessed entry.
    pub depth: u64,
    /// Maximum entries planned per scan (bounds background burstiness).
    pub max_per_scan: usize,
    /// Which planning engine runs.
    pub policy: PrefetchPolicyKind,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig {
            depth: 1,
            max_per_scan: 8,
            policy: PrefetchPolicyKind::Sequential,
        }
    }
}

/// Prefetch statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefetchStats {
    pub scans: u64,
    pub planned: u64,
    /// Entries skipped because already resident/in-flight/planned.
    pub deduped: u64,
    /// Throttle drops by the adaptive wrapper. Counts *events*, not
    /// distinct entries: a requeued hint cut again on a later scan counts
    /// again (`planned` is already netted against this, so it reads as
    /// "entries actually issued").
    pub throttled: u64,
    /// Hint entries accepted into the hint queue.
    pub hints_accepted: u64,
    /// Hint entries dropped on queue overflow.
    pub hints_dropped: u64,
}

/// Everything a planning engine may look at (all read-only: plans must be
/// deterministic functions of simulator state — no wall clock, no RNG).
pub struct PlanCtx<'a> {
    pub recent: &'a RecentList,
    pub table: &'a CacheTable,
    /// Entries a region spans (no prefetch past the end of a region).
    pub region_entries: &'a dyn Fn(RegionId) -> u64,
    pub cfg: &'a PrefetchConfig,
}

/// A prefetch planning engine. The [`Prefetcher`] shell owns the engine and
/// the configuration; the engine owns its cursor/history/queue state.
pub trait PrefetchPolicy: std::fmt::Debug {
    /// Which [`PrefetchPolicyKind`] this engine implements.
    fn kind(&self) -> PrefetchPolicyKind;

    /// Scan new recent-list entries (and any queued hints) and append
    /// planned fetches to `out` — deduplicated, in issue order.
    fn plan(&mut self, ctx: &PlanCtx<'_>, out: &mut Vec<(EntryKey, PrefetchOrigin)>);

    /// Accept frontier-hint entries for `region`, tagged with the sender's
    /// superstep. A tag different from the previous batch's invalidates
    /// whatever is still queued — undrained hints from a finished
    /// superstep are dead weight (their reads already happened). Returns
    /// how many entries were queued; engines that ignore hints accept
    /// none.
    fn accept_hint(&mut self, _region: RegionId, _entries: &[u64], _superstep: u32) -> u64 {
        0
    }

    /// A planned entry was *not* issued after all (throttled by a wrapper).
    /// Engines with one-shot sources (the hint queue) put it back; cursor-
    /// driven candidates need nothing — they self-heal on the next access.
    fn unplan(&mut self, _key: EntryKey, _origin: PrefetchOrigin) {}

    /// Re-queue an entry the DPU just invalidated on a write-back: one
    /// dirty page forced the whole multi-page entry out, and the surviving
    /// `ppe − 1` sibling pages are likely still hot. Returns `true` when
    /// the engine queued it. Cursor-driven engines decline — their next
    /// demand access re-warms the entry anyway, so re-staging it eagerly
    /// would just be blind speculation.
    fn rehint(&mut self, _key: EntryKey) -> bool {
        false
    }

    fn stats(&self) -> PrefetchStats;
}

/// Push a candidate entry unless it is resident, in flight, or already
/// planned this scan. Returns `true` when the plan hit `max_per_scan`.
fn push_candidate(
    e: EntryKey,
    origin: PrefetchOrigin,
    ctx: &PlanCtx<'_>,
    seen: &mut FxHashSet<EntryKey>,
    stats: &mut PrefetchStats,
    out: &mut Vec<(EntryKey, PrefetchOrigin)>,
) -> bool {
    // A resident entry with write-back-staled pages is *not* deduped: the
    // worker re-stages it (refresh path), healing the stale pages with
    // fresh bytes while the siblings keep serving.
    if (ctx.table.contains(e) && !ctx.table.has_stale_pages(e)) || seen.contains(&e) {
        stats.deduped += 1;
        return false;
    }
    seen.insert(e);
    out.push((e, origin));
    out.len() >= ctx.cfg.max_per_scan
}

/// `off`: plans nothing, consumes nothing.
#[derive(Debug, Default)]
pub struct OffPolicy {
    stats: PrefetchStats,
}

impl PrefetchPolicy for OffPolicy {
    fn kind(&self) -> PrefetchPolicyKind {
        PrefetchPolicyKind::Off
    }

    fn plan(&mut self, _ctx: &PlanCtx<'_>, _out: &mut Vec<(EntryKey, PrefetchOrigin)>) {
        self.stats.scans += 1;
    }

    fn stats(&self) -> PrefetchStats {
        self.stats
    }
}

/// `sequential` — the seed planner, byte-for-byte: the entry containing
/// each recently requested page plus `depth` adjacent entries ahead. The
/// in-plan dedup is a hash set alongside the ordered output vec (the seed
/// scanned the output linearly per candidate — O(n²) per scan).
#[derive(Debug, Default)]
pub struct SequentialPolicy {
    cursor: u64,
    seen: FxHashSet<EntryKey>,
    stats: PrefetchStats,
}

impl PrefetchPolicy for SequentialPolicy {
    fn kind(&self) -> PrefetchPolicyKind {
        PrefetchPolicyKind::Sequential
    }

    fn plan(&mut self, ctx: &PlanCtx<'_>, out: &mut Vec<(EntryKey, PrefetchOrigin)>) {
        self.stats.scans += 1;
        let new = ctx.recent.since(self.cursor);
        self.cursor = ctx.recent.seq();
        let ppe = ctx.table.pages_per_entry();
        self.seen.clear();
        for page in new {
            let base = EntryKey::containing(page, ppe);
            let limit = (ctx.region_entries)(page.region);
            // The accessed entry itself, then `depth` adjacent ones ahead.
            for delta in 0..=ctx.cfg.depth {
                let e = EntryKey {
                    region: base.region,
                    entry: base.entry + delta,
                };
                if e.entry >= limit {
                    break;
                }
                if push_candidate(
                    e,
                    PrefetchOrigin::Scan,
                    ctx,
                    &mut self.seen,
                    &mut self.stats,
                    out,
                ) {
                    self.stats.planned += out.len() as u64;
                    return;
                }
            }
        }
        self.stats.planned += out.len() as u64;
    }

    fn stats(&self) -> PrefetchStats {
        self.stats
    }
}

/// `strided` — detects a constant page stride per region in the recent
/// list (two consecutive equal non-zero deltas confirm it) and plans the
/// entries containing `page + k·stride` for `k = 1..=depth`; until a
/// stride is confirmed it behaves exactly like `sequential`.
#[derive(Debug, Default)]
pub struct StridedPolicy {
    cursor: u64,
    seen: FxHashSet<EntryKey>,
    /// region → (last page, last delta); a stride is confirmed when the
    /// current delta repeats the stored one.
    hist: FxHashMap<RegionId, (u64, i64)>,
    stats: PrefetchStats,
}

impl PrefetchPolicy for StridedPolicy {
    fn kind(&self) -> PrefetchPolicyKind {
        PrefetchPolicyKind::Strided
    }

    fn plan(&mut self, ctx: &PlanCtx<'_>, out: &mut Vec<(EntryKey, PrefetchOrigin)>) {
        self.stats.scans += 1;
        let new = ctx.recent.since(self.cursor);
        self.cursor = ctx.recent.seq();
        let ppe = ctx.table.pages_per_entry();
        self.seen.clear();
        for page in new {
            let limit = (ctx.region_entries)(page.region);
            let base = EntryKey::containing(page, ppe);
            let (stride, confirmed) = match self.hist.get(&page.region) {
                Some(&(last, delta)) => {
                    let d = page.page as i64 - last as i64;
                    (d, d != 0 && d == delta)
                }
                None => (0, false),
            };
            self.hist.insert(page.region, (page.page, stride));
            if base.entry < limit
                && push_candidate(
                    base,
                    PrefetchOrigin::Scan,
                    ctx,
                    &mut self.seen,
                    &mut self.stats,
                    out,
                )
            {
                self.stats.planned += out.len() as u64;
                return;
            }
            for k in 1..=ctx.cfg.depth {
                let e = if confirmed {
                    let p = page.page as i64 + stride * k as i64;
                    if p < 0 {
                        break;
                    }
                    EntryKey {
                        region: page.region,
                        entry: p as u64 / ppe,
                    }
                } else {
                    EntryKey {
                        region: base.region,
                        entry: base.entry + k,
                    }
                };
                if e.entry >= limit {
                    break;
                }
                if push_candidate(
                    e,
                    PrefetchOrigin::Scan,
                    ctx,
                    &mut self.seen,
                    &mut self.stats,
                    out,
                ) {
                    self.stats.planned += out.len() as u64;
                    return;
                }
            }
        }
        self.stats.planned += out.len() as u64;
    }

    fn stats(&self) -> PrefetchStats {
        self.stats
    }
}

/// Bound on queued hint entries. On overflow the *oldest* queued hint is
/// evicted (counted in `hints_dropped`, not silent): new hints describe the
/// most imminent reads, so they always win over leftovers.
pub const HINT_QUEUE_CAP: usize = 1 << 16;

/// `graph-hint` — application-guided: the host posts the next frontier's
/// adjacency-entry spans over the hint channel; the planner stages the
/// accessed entry (demand warmth, no speculation) plus queued hint entries
/// in FIFO order, paced at `max_per_scan` per worker wake-up so a large
/// frontier drains gradually instead of flooding the background link.
#[derive(Debug, Default)]
pub struct GraphHintPolicy {
    cursor: u64,
    seen: FxHashSet<EntryKey>,
    queue: VecDeque<EntryKey>,
    queued: FxHashSet<EntryKey>,
    /// Superstep tag of the last accepted batch; a different tag means the
    /// previous superstep finished — its undrained hints are stale.
    superstep: Option<u32>,
    stats: PrefetchStats,
}

impl PrefetchPolicy for GraphHintPolicy {
    fn kind(&self) -> PrefetchPolicyKind {
        PrefetchPolicyKind::GraphHint
    }

    fn accept_hint(&mut self, region: RegionId, entries: &[u64], superstep: u32) -> u64 {
        if self.superstep != Some(superstep) {
            // New superstep: whatever is still queued describes reads that
            // already happened (or never will) — drop it wholesale so the
            // fresh frontier drains from the front of an empty queue.
            // Single-sender assumption: tags come from one host agent's
            // monotone counter. Two co-running hint senders would clear
            // each other's queues here — per-sender queues are the
            // "multi-tenant hint fairness" item on the ROADMAP (no
            // in-repo flow posts hints from two processes today).
            self.stats.hints_dropped += self.queue.len() as u64;
            self.queue.clear();
            self.queued.clear();
            self.superstep = Some(superstep);
        }
        let mut accepted = 0;
        for &entry in entries {
            let key = EntryKey { region, entry };
            if self.queued.contains(&key) {
                continue;
            }
            if self.queue.len() >= HINT_QUEUE_CAP {
                // Evict the oldest hint: imminent reads beat leftovers.
                if let Some(old) = self.queue.pop_front() {
                    self.queued.remove(&old);
                    self.stats.hints_dropped += 1;
                }
            }
            self.queue.push_back(key);
            self.queued.insert(key);
            accepted += 1;
        }
        self.stats.hints_accepted += accepted;
        accepted
    }

    fn rehint(&mut self, key: EntryKey) -> bool {
        // Deliberately leaves the superstep tag alone: a write-back
        // re-hint is not a new frontier, just a refresh of the current
        // one, so it must survive same-superstep hint batches and be
        // cleared with them when the superstep really advances.
        if self.queued.contains(&key) {
            return true;
        }
        if self.queue.len() >= HINT_QUEUE_CAP {
            self.stats.hints_dropped += 1;
            return false;
        }
        self.queue.push_back(key);
        self.queued.insert(key);
        self.stats.hints_accepted += 1;
        true
    }

    fn unplan(&mut self, key: EntryKey, origin: PrefetchOrigin) {
        // A throttled hint goes back to the *front* of the queue (it was
        // next in line) so the wrapper's truncation never loses it.
        if origin != PrefetchOrigin::Hint || self.queued.contains(&key) {
            return;
        }
        if self.queue.len() >= HINT_QUEUE_CAP {
            // Can't requeue a full queue (unreachable in practice: plan()
            // popped this entry, making room) — count the loss, never
            // drop silently.
            self.stats.hints_dropped += 1;
            return;
        }
        self.queue.push_front(key);
        self.queued.insert(key);
    }

    fn plan(&mut self, ctx: &PlanCtx<'_>, out: &mut Vec<(EntryKey, PrefetchOrigin)>) {
        self.stats.scans += 1;
        let new = ctx.recent.since(self.cursor);
        self.cursor = ctx.recent.seq();
        let ppe = ctx.table.pages_per_entry();
        self.seen.clear();
        // Demand warmth: only the accessed entry — the hints carry the
        // look-ahead, so there is no blind adjacent speculation to waste.
        for page in new {
            let base = EntryKey::containing(page, ppe);
            if base.entry >= (ctx.region_entries)(page.region) {
                continue;
            }
            if push_candidate(base, PrefetchOrigin::Scan, ctx, &mut self.seen, &mut self.stats, out)
            {
                self.stats.planned += out.len() as u64;
                return;
            }
        }
        // Drain queued hints, paced by cache readahead headroom: staged-
        // but-unread entries may occupy at most half the table, so the
        // drain rate tracks the demand consumption rate instead of
        // flooding a small cache with entries that evict each other
        // before their superstep reads them. Undrained hints stay queued
        // for the next worker wake-up.
        let s = ctx.table.stats();
        let readahead_cap = (ctx.table.slot_count() as u64 / 2).max(1);
        let mut headroom = readahead_cap.saturating_sub(s.resident_untouched) as usize;
        while headroom > 0 && out.len() < ctx.cfg.max_per_scan {
            let Some(key) = self.queue.pop_front() else {
                break;
            };
            self.queued.remove(&key);
            if key.entry >= (ctx.region_entries)(key.region) {
                continue; // stale hint (region shrank/freed)
            }
            let before = out.len();
            let full = push_candidate(
                key,
                PrefetchOrigin::Hint,
                ctx,
                &mut self.seen,
                &mut self.stats,
                out,
            );
            if out.len() > before {
                headroom -= 1;
            }
            if full {
                break;
            }
        }
        self.stats.planned += out.len() as u64;
    }

    fn stats(&self) -> PrefetchStats {
        self.stats
    }
}

/// Insertions the adaptive throttle lets through before the traffic budget
/// starts gating (the table needs some resolved outcomes to measure
/// accuracy).
const ADAPTIVE_BOOTSTRAP_INSERTS: u64 = 8;
/// Resolved outcomes (useful + wasted) before the accuracy tiers engage.
const ADAPTIVE_MIN_RESOLVED: u64 = 4;
/// Accuracy above which the base engine runs unthrottled.
const ADAPTIVE_ACC_HIGH: f64 = 0.5;
/// Accuracy below which prefetching drops to a probe trickle.
const ADAPTIVE_ACC_LOW: f64 = 0.25;
/// Scan period of the low-accuracy probe trickle (one entry every N scans,
/// so the engine keeps sampling whether the phase changed).
const ADAPTIVE_PROBE_PERIOD: u64 = 8;
/// Scans the accuracy window spans. Gate 2 judges the useful/wasted delta
/// over the last `ADAPTIVE_ACC_WINDOW` scans instead of the whole run, so
/// an access-phase change (or a fault-induced accuracy dip) recovers
/// within one window instead of having to repay the entire historical
/// deficit.
const ADAPTIVE_ACC_WINDOW: usize = 32;

/// `adaptive` — wraps a base engine with accuracy-driven throttling. Two
/// gates, both deterministic functions of the cache table's exact
/// useful/wasted accounting:
///
/// 1. **net-traffic budget** — prefetched pages must stay amortized by
///    cache hits plus a 5 % demand-miss allowance: the per-scan budget is
///    the exact entry headroom of `hits + misses/20 + bootstrap −
///    insertions·ppe`, so spent prefetch pages never exceed the credit.
///    Since every hit is a demand page the baseline would have fetched,
///    total traffic stays ≤ ~1.05× prefetch-off by construction — inside
///    the 10 % bound the CI prefetch guard enforces;
/// 2. **accuracy tiers** — measured over a *sliding window* of the last
///    [`ADAPTIVE_ACC_WINDOW`] scans (cumulative counters sampled per scan,
///    deltas taken against the oldest sample): high accuracy runs the base
///    plan in full, mid accuracy truncates to a quarter of `max_per_scan`,
///    low accuracy keeps a 1-entry probe every [`ADAPTIVE_PROBE_PERIOD`]
///    scans. The window is what makes recovery fast: after a phase change
///    the old phase's waste ages out in one window instead of dragging the
///    lifetime average down forever.
#[derive(Debug)]
pub struct AdaptivePolicy {
    base: AdaptiveBase,
    inner: Box<dyn PrefetchPolicy>,
    scans: u64,
    throttled: u64,
    /// Per-scan snapshots of the table's cumulative (useful, wasted)
    /// counters; Gate 2 reads the delta against the oldest snapshot.
    acc_window: VecDeque<(u64, u64)>,
}

impl AdaptivePolicy {
    pub fn new(base: AdaptiveBase, inner: Box<dyn PrefetchPolicy>) -> Self {
        AdaptivePolicy {
            base,
            inner,
            scans: 0,
            throttled: 0,
            acc_window: VecDeque::new(),
        }
    }
}

impl PrefetchPolicy for AdaptivePolicy {
    fn kind(&self) -> PrefetchPolicyKind {
        PrefetchPolicyKind::Adaptive(self.base)
    }

    fn accept_hint(&mut self, region: RegionId, entries: &[u64], superstep: u32) -> u64 {
        self.inner.accept_hint(region, entries, superstep)
    }

    fn rehint(&mut self, key: EntryKey) -> bool {
        self.inner.rehint(key)
    }

    fn plan(&mut self, ctx: &PlanCtx<'_>, out: &mut Vec<(EntryKey, PrefetchOrigin)>) {
        self.scans += 1;
        let s = ctx.table.stats();
        // Slide the accuracy window on every scan — including empty ones —
        // so stale history keeps aging out while the engine idles.
        let (win_useful0, win_wasted0) = *self.acc_window.front().unwrap_or(&(0, 0));
        self.acc_window.push_back((s.prefetch_useful, s.prefetch_wasted));
        if self.acc_window.len() > ADAPTIVE_ACC_WINDOW {
            self.acc_window.pop_front();
        }
        // The inner plan always runs so its cursor keeps consuming the
        // recent list; the throttle truncates the issue list afterwards.
        self.inner.plan(ctx, out);
        if out.is_empty() {
            return;
        }
        let ppe = ctx.table.pages_per_entry().max(1);
        // Gate 1 — exact entry headroom of the net-traffic budget. This
        // gate stays cumulative on purpose: the ≤ ~1.05× traffic bound is
        // a whole-run invariant, not a windowed one.
        let spent_pages = s.insertions * ppe;
        let credit_pages = s.hits + s.misses / 20 + ADAPTIVE_BOOTSTRAP_INSERTS * ppe;
        let headroom = (credit_pages.saturating_sub(spent_pages) / ppe) as usize;
        // Gate 2 — accuracy tier over the sliding window.
        let useful = s.prefetch_useful - win_useful0;
        let wasted = s.prefetch_wasted - win_wasted0;
        let resolved = useful + wasted;
        let acc = if resolved == 0 {
            0.0
        } else {
            useful as f64 / resolved as f64
        };
        let tier = if resolved < ADAPTIVE_MIN_RESOLVED || acc >= ADAPTIVE_ACC_HIGH {
            out.len()
        } else if acc >= ADAPTIVE_ACC_LOW {
            (ctx.cfg.max_per_scan / 4).max(1)
        } else if self.scans % ADAPTIVE_PROBE_PERIOD == 0 {
            1
        } else {
            0
        };
        let budget = tier.min(headroom);
        if out.len() > budget {
            self.throttled += (out.len() - budget) as u64;
            // Hand one-shot candidates (hint-queue entries) back to the
            // inner engine, in reverse so push-front restores their order.
            for (key, origin) in out.drain(budget..).rev() {
                self.inner.unplan(key, origin);
            }
        }
    }

    fn stats(&self) -> PrefetchStats {
        let mut s = self.inner.stats();
        s.throttled = self.throttled;
        // The inner engine counted every drained candidate as planned, but
        // requeued hints re-drain on later scans; netting out the throttle
        // makes `planned` mean "entries actually issued".
        s.planned = s.planned.saturating_sub(self.throttled);
        s
    }
}

/// The prefetch worker's planner shell: owns the configuration and the
/// selected engine. This is what [`DpuAgent`](crate::dpu::DpuAgent) drives
/// on every recorded access and on every received hint.
#[derive(Debug)]
pub struct Prefetcher {
    pub cfg: PrefetchConfig,
    engine: Box<dyn PrefetchPolicy>,
}

impl Default for Prefetcher {
    fn default() -> Self {
        Prefetcher::new(PrefetchConfig::default())
    }
}

impl Prefetcher {
    pub fn new(cfg: PrefetchConfig) -> Self {
        Prefetcher {
            engine: cfg.policy.build(),
            cfg,
        }
    }

    pub fn policy(&self) -> PrefetchPolicyKind {
        self.engine.kind()
    }

    pub fn wants_hints(&self) -> bool {
        self.cfg.policy.wants_hints()
    }

    pub fn stats(&self) -> PrefetchStats {
        self.engine.stats()
    }

    /// Feed frontier-hint entries to the engine; returns how many queued.
    /// `superstep` scopes the hints — a new tag invalidates undrained
    /// leftovers from the previous batch.
    pub fn accept_hint(&mut self, region: RegionId, entries: &[u64], superstep: u32) -> u64 {
        self.engine.accept_hint(region, entries, superstep)
    }

    /// Re-queue an entry a write-back just invalidated (its surviving
    /// sibling pages are still hot). Hint engines queue it; cursor-driven
    /// engines decline. Returns whether the entry was queued.
    pub fn rehint(&mut self, key: EntryKey) -> bool {
        self.engine.rehint(key)
    }

    /// Scan new recent-list entries (and queued hints) and plan entry
    /// fetches. `region_entries(region)` bounds the entry index (no
    /// prefetch past the end of a region). Returns deduplicated
    /// `(entry, provenance)` pairs in plan order.
    pub fn plan(
        &mut self,
        recent: &RecentList,
        table: &CacheTable,
        region_entries: impl Fn(RegionId) -> u64,
    ) -> Vec<(EntryKey, PrefetchOrigin)> {
        let mut out = Vec::new();
        let ctx = PlanCtx {
            recent,
            table,
            region_entries: &region_entries,
            cfg: &self.cfg,
        };
        self.engine.plan(&ctx, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::host::buffer::PageKey;
    use crate::sim::rng::Rng;

    fn table() -> CacheTable {
        // 64 slots of 4 pages (1 KB pages).
        CacheTable::new(64 * 4096, 4096, 1024)
    }

    fn prefetcher(policy: PrefetchPolicyKind) -> Prefetcher {
        Prefetcher::new(PrefetchConfig {
            policy,
            ..PrefetchConfig::default()
        })
    }

    fn plan_for(pages: &[u64], t: &CacheTable, p: &mut Prefetcher) -> Vec<u64> {
        let mut r = RecentList::new(128);
        for &pg in pages {
            r.push(PageKey::new(1, pg));
        }
        p.plan(&r, t, |_| 1_000).iter().map(|(e, _)| e.entry).collect()
    }

    #[test]
    fn plans_accessed_and_adjacent_entry() {
        let t = table();
        let mut p = Prefetcher::default();
        // Page 5 -> entry 1; plan entries 1 and 2.
        assert_eq!(plan_for(&[5], &t, &mut p), vec![1, 2]);
        assert_eq!(p.policy(), PrefetchPolicyKind::Sequential);
    }

    #[test]
    fn stale_entries_bypass_residency_dedup() {
        let mut t = table();
        let mut rng = Rng::new(0);
        t.insert(EntryKey { region: 1, entry: 1 }, vec![0; 4096], 0, &mut rng);
        t.insert(EntryKey { region: 1, entry: 2 }, vec![0; 4096], 0, &mut rng);
        let mut p = Prefetcher::default();
        assert!(plan_for(&[5], &t, &mut p).is_empty(), "resident entries dedup");
        assert_eq!(p.stats().deduped, 2);
        // A write-back stales page 5: its entry re-plans (refresh heals the
        // dirty page); the clean adjacent entry still dedups.
        t.invalidate_page(PageKey::new(1, 5));
        let mut p2 = Prefetcher::default();
        assert_eq!(plan_for(&[5], &t, &mut p2), vec![1], "stale entry re-planned");
    }

    #[test]
    fn dedups_resident_entries() {
        let mut t = table();
        let mut rng = Rng::new(0);
        t.insert(EntryKey { region: 1, entry: 1 }, vec![0; 4096], 0, &mut rng);
        let mut p = Prefetcher::default();
        assert_eq!(plan_for(&[5], &t, &mut p), vec![2]);
        assert_eq!(p.stats().deduped, 1);
    }

    #[test]
    fn respects_region_bounds() {
        let t = table();
        let mut p = Prefetcher::default();
        let mut r = RecentList::new(128);
        r.push(PageKey::new(1, 7)); // entry 1 of a 2-entry region
        let planned = p.plan(&r, &t, |_| 2);
        assert_eq!(planned.iter().map(|(e, _)| e.entry).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn cursor_consumes_only_new_accesses() {
        let t = table();
        let mut p = Prefetcher::default();
        let mut r = RecentList::new(128);
        r.push(PageKey::new(1, 0));
        let first = p.plan(&r, &t, |_| 1_000);
        assert!(!first.is_empty());
        // Nothing new: next scan plans nothing.
        assert!(p.plan(&r, &t, |_| 1_000).is_empty());
        r.push(PageKey::new(1, 40));
        let second = p.plan(&r, &t, |_| 1_000);
        assert_eq!(second[0].0.entry, 10);
    }

    #[test]
    fn scan_bound_caps_burst() {
        let t = table();
        let mut p = Prefetcher::new(PrefetchConfig {
            depth: 1,
            max_per_scan: 3,
            policy: PrefetchPolicyKind::Sequential,
        });
        let planned = plan_for(&[0, 8, 16, 24, 32], &t, &mut p);
        assert_eq!(planned.len(), 3);
    }

    #[test]
    fn depth_zero_fetches_only_accessed_entry() {
        let t = table();
        let mut p = Prefetcher::new(PrefetchConfig {
            depth: 0,
            max_per_scan: 8,
            policy: PrefetchPolicyKind::Sequential,
        });
        assert_eq!(plan_for(&[5], &t, &mut p), vec![1]);
    }

    // ---- sequential reference-model equivalence -------------------------

    /// The seed's planner, verbatim (linear `out.contains` dedup) — the
    /// reference model the default engine must match byte-for-byte.
    struct SeedReference {
        cfg: PrefetchConfig,
        cursor: u64,
        planned: u64,
        deduped: u64,
    }

    impl SeedReference {
        fn plan(
            &mut self,
            recent: &RecentList,
            table: &CacheTable,
            region_entries: impl Fn(RegionId) -> u64,
        ) -> Vec<EntryKey> {
            let new = recent.since(self.cursor);
            self.cursor = recent.seq();
            let ppe = table.pages_per_entry();
            let mut out: Vec<EntryKey> = Vec::new();
            for page in new {
                let base = EntryKey::containing(page, ppe);
                let limit = region_entries(page.region);
                for delta in 0..=self.cfg.depth {
                    let e = EntryKey {
                        region: base.region,
                        entry: base.entry + delta,
                    };
                    if e.entry >= limit {
                        break;
                    }
                    if table.contains(e) || out.contains(&e) {
                        self.deduped += 1;
                        continue;
                    }
                    out.push(e);
                    if out.len() >= self.cfg.max_per_scan {
                        self.planned += out.len() as u64;
                        return out;
                    }
                }
            }
            self.planned += out.len() as u64;
            out
        }
    }

    /// Default-policy regression: identical planned-entry order and
    /// identical counters vs the seed reference on randomized access
    /// streams with residency churn.
    #[test]
    fn sequential_matches_seed_reference_model() {
        let mut rng = Rng::new(0x5E9);
        for case in 0..50 {
            let cfg = PrefetchConfig {
                depth: rng.below(6),
                max_per_scan: 1 + rng.index(12),
                policy: PrefetchPolicyKind::Sequential,
            };
            let mut p = Prefetcher::new(cfg);
            let mut reference = SeedReference {
                cfg,
                cursor: 0,
                planned: 0,
                deduped: 0,
            };
            let mut t = table();
            let mut trng = Rng::new(case);
            let mut r = RecentList::new(32);
            for _ in 0..8 {
                // Random access burst + random resident entries.
                for _ in 0..rng.below(12) {
                    r.push(PageKey::new(1, rng.below(120)));
                }
                if trng.chance(0.5) {
                    let e = EntryKey { region: 1, entry: trng.below(30) };
                    t.insert(e, vec![0; 4096], 0, &mut trng);
                }
                let ours: Vec<EntryKey> =
                    p.plan(&r, &t, |_| 30).into_iter().map(|(e, _)| e).collect();
                let seed = reference.plan(&r, &t, |_| 30);
                assert_eq!(ours, seed, "case {case}: plan order diverged");
            }
            assert_eq!(p.stats().planned, reference.planned, "case {case}");
            assert_eq!(p.stats().deduped, reference.deduped, "case {case}");
        }
    }

    // ---- other engines --------------------------------------------------

    #[test]
    fn off_policy_plans_nothing() {
        let t = table();
        let mut p = prefetcher(PrefetchPolicyKind::Off);
        assert!(plan_for(&[0, 5, 9], &t, &mut p).is_empty());
        assert_eq!(p.stats().planned, 0);
        assert!(!p.wants_hints());
    }

    #[test]
    fn strided_confirms_stride_and_jumps() {
        let t = table();
        let mut p = Prefetcher::new(PrefetchConfig {
            depth: 2,
            max_per_scan: 16,
            policy: PrefetchPolicyKind::Strided,
        });
        // Pages 0, 8, 16: delta 8 twice -> confirmed on the third access.
        // Entry stride = 8 pages / 4 ppe = 2 entries.
        let planned = plan_for(&[0, 8, 16], &t, &mut p);
        // Accessed entries 0, 2, 4; predictions from page 16: 24->e6, 32->e8.
        assert!(planned.contains(&6) && planned.contains(&8), "{planned:?}");
    }

    #[test]
    fn strided_falls_back_to_adjacent_before_confirmation() {
        let t = table();
        let mut seq = prefetcher(PrefetchPolicyKind::Sequential);
        let mut st = prefetcher(PrefetchPolicyKind::Strided);
        // A single access: no stride history -> identical to sequential.
        assert_eq!(plan_for(&[5], &t, &mut st), plan_for(&[5], &t, &mut seq));
    }

    #[test]
    fn graph_hint_queues_and_drains_in_fifo_order() {
        let t = table();
        let mut p = Prefetcher::new(PrefetchConfig {
            depth: 1,
            max_per_scan: 3,
            policy: PrefetchPolicyKind::GraphHint,
        });
        assert!(p.wants_hints());
        assert_eq!(p.accept_hint(1, &[7, 9, 7, 11, 13], 0), 4, "in-queue dedup");
        let r = RecentList::new(8);
        let planned = p.plan(&r, &t, |_| 1_000);
        assert_eq!(
            planned.iter().map(|(e, _)| e.entry).collect::<Vec<_>>(),
            vec![7, 9, 11],
            "FIFO drain capped at max_per_scan"
        );
        assert!(planned.iter().all(|(_, o)| *o == PrefetchOrigin::Hint));
        // Next scan drains the remainder.
        let rest = p.plan(&r, &t, |_| 1_000);
        assert_eq!(rest.iter().map(|(e, _)| e.entry).collect::<Vec<_>>(), vec![13]);
        assert_eq!(p.stats().hints_accepted, 4);
    }

    #[test]
    fn graph_hint_skips_resident_and_out_of_region_hints() {
        let mut t = table();
        let mut rng = Rng::new(0);
        t.insert(EntryKey { region: 1, entry: 5 }, vec![0; 4096], 0, &mut rng);
        let mut p = prefetcher(PrefetchPolicyKind::GraphHint);
        p.accept_hint(1, &[5, 6, 999], 0);
        let r = RecentList::new(8);
        let planned = p.plan(&r, &t, |_| 10);
        assert_eq!(planned.iter().map(|(e, _)| e.entry).collect::<Vec<_>>(), vec![6]);
    }

    #[test]
    fn graph_hint_rehint_requeues_invalidated_entry() {
        let t = table();
        let mut p = prefetcher(PrefetchPolicyKind::GraphHint);
        p.accept_hint(1, &[3], 0);
        let r = RecentList::new(8);
        assert_eq!(p.plan(&r, &t, |_| 1_000).len(), 1);
        // A write-back invalidation re-queues the entry without touching
        // the superstep tag: the next same-superstep hint batch must not
        // clear it.
        assert!(p.rehint(EntryKey { region: 1, entry: 3 }));
        p.accept_hint(1, &[5], 0);
        let planned: Vec<u64> =
            p.plan(&r, &t, |_| 1_000).iter().map(|(e, _)| e.entry).collect();
        assert_eq!(planned, vec![3, 5], "rehint drains ahead of newer hints");
        // Cursor-driven engines decline rehints (demand access self-heals).
        let mut seq = Prefetcher::default();
        assert!(!seq.rehint(EntryKey { region: 1, entry: 3 }));
    }

    #[test]
    fn graph_hint_still_warms_accessed_entry() {
        let t = table();
        let mut p = prefetcher(PrefetchPolicyKind::GraphHint);
        // No hints queued: behaves like depth-0 sequential.
        assert_eq!(plan_for(&[5], &t, &mut p), vec![1]);
    }

    #[test]
    fn adaptive_bootstraps_then_throttles_on_pure_waste() {
        let mut t = table();
        let mut rng = Rng::new(7);
        let mut p = Prefetcher::new(PrefetchConfig {
            depth: 1,
            max_per_scan: 8,
            policy: PrefetchPolicyKind::Adaptive(AdaptiveBase::Sequential),
        });
        assert_eq!(p.policy().name(), "adaptive");
        let mut r = RecentList::new(128);
        let mut issued = 0u64;
        // Never look anything up: every insert stays unresolved, then gets
        // evicted untouched -> accuracy collapses, throttle must bite.
        for i in 0..400u64 {
            r.push(PageKey::new(1, (i * 16) % 4096));
            for (e, _) in p.plan(&r, &t, |_| 2_000) {
                t.insert(e, vec![0; 4096], 0, &mut rng);
                issued += 1;
            }
        }
        assert!(p.stats().throttled > 0, "throttle never engaged");
        assert!(
            issued < 400,
            "wasteful prefetching must be cut well below one entry per access ({issued})"
        );
    }

    #[test]
    fn adaptive_runs_full_rate_while_accurate() {
        let mut t = table();
        let mut rng = Rng::new(3);
        let mut p = Prefetcher::new(PrefetchConfig {
            depth: 1,
            max_per_scan: 8,
            policy: PrefetchPolicyKind::Adaptive(AdaptiveBase::Sequential),
        });
        let mut r = RecentList::new(128);
        let mut planned_total = 0;
        // Sequential scan where every prefetched entry is hit right away:
        // accuracy stays high, budget stays earned -> no starvation.
        for page in 0..128u64 {
            r.push(PageKey::new(1, page));
            for (e, _) in p.plan(&r, &t, |_| 1_000) {
                t.insert(e, vec![0; 4096], 0, &mut rng);
                planned_total += 1;
            }
            t.lookup_page(10, PageKey::new(1, page));
        }
        assert!(
            planned_total >= 30,
            "accurate prefetching must keep flowing ({planned_total})"
        );
    }

    /// Hints are one-shot queue entries: when the adaptive throttle cuts a
    /// drained hint, it must be requeued (in order), not lost — once the
    /// budget gate reopens, every hinted entry still gets issued.
    #[test]
    fn adaptive_graph_hint_requeues_throttled_hints() {
        let mut t = table();
        let mut rng = Rng::new(1);
        let mut p = Prefetcher::new(PrefetchConfig {
            depth: 0,
            max_per_scan: 4,
            policy: PrefetchPolicyKind::Adaptive(AdaptiveBase::GraphHint),
        });
        assert!(p.wants_hints());
        let hinted: Vec<u64> = (0..20).collect();
        assert_eq!(p.accept_hint(1, &hinted, 0), 20);
        let r = RecentList::new(8);
        let mut staged: Vec<EntryKey> = Vec::new();
        // Phase 1: no feedback — after the bootstrap the traffic-budget
        // gate closes; drained hints must survive the truncation.
        for _ in 0..10 {
            for (e, _) in p.plan(&r, &t, |_| 1_000) {
                t.insert(e, vec![0; 4096], 0, &mut rng);
                staged.push(e);
            }
        }
        assert!(p.stats().throttled > 0, "gate must have engaged");
        assert!(staged.len() < 20, "gate must have paused issuance");
        // Phase 2: consume what was staged — hits earn the budget back and
        // the surviving queue must drain completely.
        for _ in 0..50 {
            for e in staged.clone() {
                for pg in 0..4u64 {
                    t.lookup_page(10, PageKey::new(e.region, e.entry * 4 + pg));
                }
            }
            for (e, _) in p.plan(&r, &t, |_| 1_000) {
                t.insert(e, vec![0; 4096], 0, &mut rng);
                staged.push(e);
            }
        }
        let mut got: Vec<u64> = staged.iter().map(|e| e.entry).collect();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got, hinted, "no hinted entry may be lost to throttling");
    }

    /// Accuracy is judged over a sliding window, not a lifetime average: a
    /// workload that prefetched garbage for a long phase and then turns
    /// sequential must see the throttle reopen within ~one window of good
    /// outcomes instead of repaying the whole historical deficit first.
    #[test]
    fn adaptive_accuracy_window_recovers_after_phase_change() {
        let mut t = table();
        let mut rng = Rng::new(11);
        let mut p = Prefetcher::new(PrefetchConfig {
            depth: 1,
            max_per_scan: 8,
            policy: PrefetchPolicyKind::Adaptive(AdaptiveBase::Sequential),
        });
        let mut r = RecentList::new(128);
        // Phase 1: scattered accesses. One pinned hot entry keeps the
        // traffic budget earning (plenty of repeat hits) while every other
        // staged entry rots unread — it is *accuracy* that collapses here,
        // not the byte budget.
        let mut hot: Option<EntryKey> = None;
        for i in 0..200u64 {
            r.push(PageKey::new(1, (i * 16) % 4096));
            for (e, _) in p.plan(&r, &t, |_| 1 << 20) {
                t.insert(e, vec![0; 4096], 0, &mut rng);
                if hot.is_none() {
                    t.pin(e);
                    hot = Some(e);
                }
            }
            if let Some(h) = hot {
                for pg in 0..4u64 {
                    t.lookup_page(10, PageKey::new(1, h.entry * 4 + pg));
                }
            }
        }
        assert!(p.stats().throttled > 0, "waste phase must throttle");
        // Phase 2: perfectly sequential and fully consumed. The window
        // forgets the waste phase after ~ADAPTIVE_ACC_WINDOW scans; a
        // cumulative average would stay pinned low and trickle on.
        let mut staged: Vec<EntryKey> = Vec::new();
        let mut issued_late = 0u64;
        for i in 0..120u64 {
            r.push(PageKey::new(1, 8192 + i));
            for (e, _) in p.plan(&r, &t, |_| 1 << 20) {
                t.insert(e, vec![0; 4096], 0, &mut rng);
                staged.push(e);
                if i >= 60 {
                    issued_late += 1;
                }
            }
            // First touches resolve "useful"; repeat hits earn traffic
            // budget back.
            for e in staged.clone() {
                for pg in 0..4u64 {
                    t.lookup_page(10, PageKey::new(e.region, e.entry * 4 + pg));
                }
            }
        }
        assert!(
            issued_late >= 10,
            "windowed accuracy must reopen the throttle after the phase change ({issued_late})"
        );
    }

    #[test]
    fn policy_names_round_trip() {
        for kind in PrefetchPolicyKind::ALL {
            assert_eq!(PrefetchPolicyKind::parse(kind.name()), Some(kind));
        }
        for kind in [
            PrefetchPolicyKind::Adaptive(AdaptiveBase::Strided),
            PrefetchPolicyKind::Adaptive(AdaptiveBase::GraphHint),
        ] {
            assert_eq!(PrefetchPolicyKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(
            PrefetchPolicyKind::parse("ADAPTIVE"),
            Some(PrefetchPolicyKind::Adaptive(AdaptiveBase::Sequential))
        );
        assert_eq!(PrefetchPolicyKind::parse("nope"), None);
    }

    #[test]
    fn build_produces_matching_kind() {
        for kind in PrefetchPolicyKind::ALL {
            assert_eq!(kind.build().kind(), kind);
        }
        assert_eq!(
            PrefetchPolicyKind::Adaptive(AdaptiveBase::GraphHint).build().kind(),
            PrefetchPolicyKind::Adaptive(AdaptiveBase::GraphHint)
        );
    }
}
