//! DPU agent — SODA's SmartNIC offload target (§III).
//!
//! Everything that runs on the BlueField SoC in the paper lives here:
//! request handling, task aggregation, the asynchronous forwarding
//! pipeline, the two caching strategies with their supporting data
//! structures (recent list, cache table, static cache, prefetcher), and
//! the operator-pushdown kernels the background cores run next to the
//! data ([`kernel`]).

pub mod agent;
pub mod aggregate;
pub mod cache_table;
pub mod kernel;
pub mod pipeline;
pub mod prefetch;
pub mod recent_list;
pub mod static_cache;

pub use agent::{DpuAgent, DpuConfig, DpuOpts, DpuStats, DpuTiming, ReadOutcome, Source};
pub use kernel::{KernelRun, MINLABEL_NOT_FRONTIER};
pub use aggregate::Aggregator;
pub use cache_table::{CacheStats, CacheTable, EntryKey, PageInvalidate, PrefetchOrigin};
pub use pipeline::{ForwardMode, Forwarder};
pub use prefetch::{
    AdaptiveBase, PrefetchConfig, PrefetchPolicy, PrefetchPolicyKind, PrefetchStats, Prefetcher,
};
pub use recent_list::RecentList;
pub use static_cache::StaticCache;
