//! Static caching — application-pinned regions in DPU DRAM (§III-A).
//!
//! "Static Caching leverages application-specific knowledge to place
//! selected data chunks into the DPU cache. [...] By extending the metadata
//! on the host agent, SODA can determine whether a page is cached in DPU or
//! choose to bypass it. Therefore, the static caching strategy can achieve
//! a 100 % hit rate on the DPU cache."
//!
//! In the graph case study the *vertex data* (CSR offsets — small, very high
//! access density) is pinned while edge data stays uncached. The region is
//! bulk-loaded from the memory node once (amortized background traffic);
//! afterwards the host reads it with the one-sided protocol directly from
//! DPU DRAM — no DPU core is involved, which is why static caching has
//! near-zero steady-state overhead.

use crate::memnode::RegionId;
use std::collections::HashMap;

/// Error conditions for static cache management.
#[derive(Debug, PartialEq, Eq)]
pub enum StaticCacheError {
    /// Region does not fit in the remaining DPU memory budget.
    InsufficientCapacity { requested: u64, available: u64 },
    AlreadyCached(RegionId),
}

impl std::fmt::Display for StaticCacheError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StaticCacheError::InsufficientCapacity { requested, available } => write!(
                f,
                "static cache: region of {requested} B exceeds available {available} B \
                 (the strategy relies on identifying small high-density regions)"
            ),
            StaticCacheError::AlreadyCached(r) => write!(f, "region {r} already static-cached"),
        }
    }
}

impl std::error::Error for StaticCacheError {}

/// Statistics for the static cache.
#[derive(Clone, Copy, Debug, Default)]
pub struct StaticCacheStats {
    /// One-sided reads served from DPU DRAM (all hits, by construction).
    pub serves: u64,
    pub served_bytes: u64,
    /// Bytes bulk-loaded from the memory node at pin time.
    pub loaded_bytes: u64,
}

/// Whole-region pinned cache in DPU DRAM.
#[derive(Debug, Default)]
pub struct StaticCache {
    capacity_bytes: u64,
    used_bytes: u64,
    regions: HashMap<RegionId, Vec<u8>>,
    stats: StaticCacheStats,
}

impl StaticCache {
    pub fn new(capacity_bytes: u64) -> Self {
        StaticCache {
            capacity_bytes,
            used_bytes: 0,
            regions: HashMap::new(),
            stats: StaticCacheStats::default(),
        }
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn stats(&self) -> StaticCacheStats {
        self.stats
    }

    /// Is this region pinned? The *host agent's* extended metadata mirrors
    /// this flag so the host can route requests without asking the DPU.
    pub fn is_cached(&self, region: RegionId) -> bool {
        self.regions.contains_key(&region)
    }

    /// Pin a full region's data. `data` is the bulk-loaded copy from the
    /// memory node (the caller charges the network transfer).
    pub fn pin_region(&mut self, region: RegionId, data: Vec<u8>) -> Result<(), StaticCacheError> {
        if self.regions.contains_key(&region) {
            return Err(StaticCacheError::AlreadyCached(region));
        }
        let bytes = data.len() as u64;
        let available = self.capacity_bytes - self.used_bytes;
        if bytes > available {
            return Err(StaticCacheError::InsufficientCapacity {
                requested: bytes,
                available,
            });
        }
        self.used_bytes += bytes;
        self.stats.loaded_bytes += bytes;
        self.regions.insert(region, data);
        Ok(())
    }

    /// Unpin a region, freeing DPU DRAM.
    pub fn unpin_region(&mut self, region: RegionId) -> bool {
        if let Some(data) = self.regions.remove(&region) {
            self.used_bytes -= data.len() as u64;
            true
        } else {
            false
        }
    }

    /// Serve `len` bytes at `offset` of a pinned region (one-sided read
    /// from DPU DRAM; guaranteed hit).
    pub fn read(&mut self, region: RegionId, offset: u64, out: &mut [u8]) -> bool {
        match self.regions.get(&region) {
            Some(data) => {
                let end = offset as usize + out.len();
                assert!(end <= data.len(), "static cache read out of bounds");
                out.copy_from_slice(&data[offset as usize..end]);
                self.stats.serves += 1;
                self.stats.served_bytes += out.len() as u64;
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_and_read_back() {
        let mut c = StaticCache::new(1024);
        c.pin_region(3, (0u8..100).collect()).unwrap();
        let mut buf = [0u8; 10];
        assert!(c.read(3, 50, &mut buf));
        assert_eq!(buf, [50, 51, 52, 53, 54, 55, 56, 57, 58, 59]);
        assert_eq!(c.stats().serves, 1);
        assert_eq!(c.stats().served_bytes, 10);
    }

    #[test]
    fn capacity_is_enforced() {
        let mut c = StaticCache::new(100);
        let err = c.pin_region(1, vec![0; 150]).unwrap_err();
        assert_eq!(
            err,
            StaticCacheError::InsufficientCapacity { requested: 150, available: 100 }
        );
        c.pin_region(1, vec![0; 60]).unwrap();
        assert!(matches!(
            c.pin_region(2, vec![0; 60]),
            Err(StaticCacheError::InsufficientCapacity { available: 40, .. })
        ));
    }

    #[test]
    fn double_pin_rejected() {
        let mut c = StaticCache::new(100);
        c.pin_region(1, vec![0; 10]).unwrap();
        assert_eq!(c.pin_region(1, vec![0; 10]).unwrap_err(), StaticCacheError::AlreadyCached(1));
    }

    #[test]
    fn unpin_frees_budget() {
        let mut c = StaticCache::new(100);
        c.pin_region(1, vec![0; 80]).unwrap();
        assert!(c.unpin_region(1));
        assert!(!c.unpin_region(1));
        assert_eq!(c.used_bytes(), 0);
        c.pin_region(2, vec![0; 80]).unwrap();
    }

    #[test]
    fn read_of_uncached_region_misses() {
        let mut c = StaticCache::new(100);
        let mut buf = [0u8; 4];
        assert!(!c.read(9, 0, &mut buf));
        assert_eq!(c.stats().serves, 0);
    }

    #[test]
    fn loaded_bytes_accumulate() {
        let mut c = StaticCache::new(1000);
        c.pin_region(1, vec![0; 300]).unwrap();
        c.pin_region(2, vec![0; 200]).unwrap();
        assert_eq!(c.stats().loaded_bytes, 500);
        assert_eq!(c.used_bytes(), 500);
    }
}
