//! The host agent's unified page buffer (§III).
//!
//! One buffer is shared by *all* FAM-backed objects and managed in
//! equal-sized data chunks (64 KB on the testbed) with an LRU policy, "to
//! ensure the local buffer is distributed to FAM-backed objects as needed".
//! Dirty chunks are written back on eviction; a *proactive eviction policy*
//! triggers when the buffer reaches a threshold load factor so that
//! evictions stay off the fault critical path.
//!
//! Implementation: fixed frame pool + intrusive doubly-linked LRU list over
//! frame indices + hash map for residency lookup. No allocation on the
//! steady-state fault path — evicted frames donate their storage to the
//! incoming page.

use crate::memnode::RegionId;
use crate::util::fxhash::FxHashMap;

/// Eviction policy of the unified buffer.
///
/// The paper's buffer is managed through `userfaultfd`, which only observes
/// page *faults* — once a chunk is mapped, later accesses are invisible to
/// the runtime (user space has no access bits). "LRU" therefore means
/// least-recently-FAULTED ([`EvictPolicy::FaultFifo`]), and hot pages churn
/// once the buffer turns over — the access-density effect that makes DPU
/// static caching pay off (Fig 9). [`EvictPolicy::AccessLru`] is the
/// idealized policy (as if access bits were free) kept for ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Order by fault time (what uffd-based management can implement).
    FaultFifo,
    /// Order by access time (idealized; requires hardware access bits).
    AccessLru,
}

/// Identity of one page (chunk) of a FAM region.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PageKey {
    pub region: RegionId,
    /// Page index within the region (page_offset / chunk_bytes).
    pub page: u64,
}

impl PageKey {
    pub fn new(region: RegionId, page: u64) -> Self {
        PageKey { region, page }
    }

    /// Byte offset of this page within its region.
    pub fn byte_offset(&self, chunk_bytes: u64) -> u64 {
        self.page * chunk_bytes
    }
}

const NIL: u32 = u32::MAX;

#[derive(Debug)]
struct Frame {
    key: PageKey,
    data: Box<[u8]>,
    dirty: bool,
    prev: u32,
    next: u32,
}

/// A page evicted from the buffer; `dirty` means it must be written back.
#[derive(Debug)]
pub struct EvictedPage {
    pub key: PageKey,
    pub data: Box<[u8]>,
    pub dirty: bool,
}

/// Buffer statistics for the host agent's metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct BufferStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions_clean: u64,
    pub evictions_dirty: u64,
}

impl BufferStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Unified LRU page buffer.
#[derive(Debug)]
pub struct PageBuffer {
    chunk_bytes: u64,
    frames: Vec<Frame>,
    map: FxHashMap<PageKey, u32>,
    /// Most-recently-used frame.
    head: u32,
    /// Least-recently-used frame.
    tail: u32,
    /// Reusable storage from freed frames.
    spare: Vec<Box<[u8]>>,
    /// Frame slots vacated by eviction, reusable by the next insert.
    free_slots: Vec<u32>,
    capacity_pages: usize,
    /// Proactive-eviction trigger: load factor above which the agent starts
    /// evicting ahead of demand (§III, "triggered when the buffer reaches a
    /// threshold load factor").
    load_threshold: f64,
    policy: EvictPolicy,
    stats: BufferStats,
}

impl PageBuffer {
    pub fn new(capacity_bytes: u64, chunk_bytes: u64, load_threshold: f64) -> Self {
        Self::with_policy(capacity_bytes, chunk_bytes, load_threshold, EvictPolicy::FaultFifo)
    }

    pub fn with_policy(
        capacity_bytes: u64,
        chunk_bytes: u64,
        load_threshold: f64,
        policy: EvictPolicy,
    ) -> Self {
        assert!(chunk_bytes > 0 && chunk_bytes.is_power_of_two());
        assert!((0.0..=1.0).contains(&load_threshold));
        let capacity_pages = (capacity_bytes / chunk_bytes).max(1) as usize;
        PageBuffer {
            chunk_bytes,
            frames: Vec::with_capacity(capacity_pages.min(1 << 20)),
            map: FxHashMap::default(),
            head: NIL,
            tail: NIL,
            spare: Vec::new(),
            free_slots: Vec::new(),
            capacity_pages,
            load_threshold,
            policy,
            stats: BufferStats::default(),
        }
    }

    pub fn policy(&self) -> EvictPolicy {
        self.policy
    }

    pub fn chunk_bytes(&self) -> u64 {
        self.chunk_bytes
    }

    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }

    pub fn resident_pages(&self) -> usize {
        self.map.len()
    }

    pub fn load_factor(&self) -> f64 {
        self.map.len() as f64 / self.capacity_pages as f64
    }

    pub fn stats(&self) -> BufferStats {
        self.stats
    }

    pub fn is_resident(&self, key: PageKey) -> bool {
        self.map.contains_key(&key)
    }

    fn unlink(&mut self, idx: u32) {
        let (prev, next) = {
            let f = &self.frames[idx as usize];
            (f.prev, f.next)
        };
        if prev != NIL {
            self.frames[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.frames[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, idx: u32) {
        let old_head = self.head;
        {
            let f = &mut self.frames[idx as usize];
            f.prev = NIL;
            f.next = old_head;
        }
        if old_head != NIL {
            self.frames[old_head as usize].prev = idx;
        } else {
            self.tail = idx;
        }
        self.head = idx;
    }

    /// Look up a page; on hit, the frame moves to MRU and its data is
    /// returned. `write` marks the frame dirty. Counts hit/miss.
    pub fn access(&mut self, key: PageKey, write: bool) -> Option<&mut [u8]> {
        match self.map.get(&key).copied() {
            Some(idx) => {
                self.stats.hits += 1;
                // AccessLru refreshes recency on every hit; FaultFifo cannot
                // see hits (uffd only reports faults), so order is untouched.
                if self.policy == EvictPolicy::AccessLru {
                    self.unlink(idx);
                    self.push_front(idx);
                }
                let f = &mut self.frames[idx as usize];
                if write {
                    f.dirty = true;
                }
                Some(&mut f.data)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Non-counting residency probe returning the data if present (used by
    /// multi-page copies after an explicit fault).
    pub fn peek(&mut self, key: PageKey) -> Option<&mut [u8]> {
        let idx = self.map.get(&key).copied()?;
        Some(&mut self.frames[idx as usize].data)
    }

    /// True if inserting one more page should be preceded by eviction(s)
    /// under the proactive policy.
    pub fn over_threshold(&self) -> bool {
        (self.map.len() + 1) as f64 > self.load_threshold * self.capacity_pages as f64
    }

    /// True if the buffer is completely full (demand eviction required).
    pub fn is_full(&self) -> bool {
        self.map.len() >= self.capacity_pages
    }

    /// Evict the LRU page, returning it for potential writeback.
    pub fn evict_lru(&mut self) -> Option<EvictedPage> {
        let idx = self.tail;
        if idx == NIL {
            return None;
        }
        self.unlink(idx);
        let frame = &mut self.frames[idx as usize];
        let key = frame.key;
        let dirty = frame.dirty;
        // Donate a fresh empty box and steal the data.
        let data = std::mem::replace(&mut frame.data, Box::from(&[][..]));
        self.map.remove(&key);
        // The frame slot becomes spare storage via the free index trick: we
        // keep indices dense by tracking spares separately.
        self.free_slots.push(idx);
        if dirty {
            self.stats.evictions_dirty += 1;
        } else {
            self.stats.evictions_clean += 1;
        }
        Some(EvictedPage { key, data, dirty })
    }

    /// Insert a page (must not be resident; caller evicts first if full).
    /// `fill` populates the frame's storage. Returns a mutable view.
    pub fn insert_with(
        &mut self,
        key: PageKey,
        dirty: bool,
        fill: impl FnOnce(&mut [u8]),
    ) -> &mut [u8] {
        assert!(!self.map.contains_key(&key), "page already resident: {key:?}");
        assert!(
            self.map.len() < self.capacity_pages,
            "buffer full; evict before insert"
        );
        let idx = if let Some(idx) = self.free_slots.pop() {
            let data = self
                .spare
                .pop()
                .unwrap_or_else(|| vec![0u8; self.chunk_bytes as usize].into_boxed_slice());
            let f = &mut self.frames[idx as usize];
            f.key = key;
            f.data = data;
            f.dirty = dirty;
            idx
        } else {
            let idx = self.frames.len() as u32;
            self.frames.push(Frame {
                key,
                data: vec![0u8; self.chunk_bytes as usize].into_boxed_slice(),
                dirty,
                prev: NIL,
                next: NIL,
            });
            idx
        };
        self.push_front(idx);
        self.map.insert(key, idx);
        let f = &mut self.frames[idx as usize];
        fill(&mut f.data);
        &mut f.data
    }

    /// Return spare storage (an evicted page's buffer after writeback) so
    /// the steady state allocates nothing.
    pub fn recycle(&mut self, data: Box<[u8]>) {
        if data.len() == self.chunk_bytes as usize {
            self.spare.push(data);
        }
    }

    /// Drain every resident dirty page (flush at deallocation / barrier).
    pub fn drain_dirty(&mut self) -> Vec<EvictedPage> {
        let mut out = Vec::new();
        let keys: Vec<PageKey> = self.map.keys().copied().collect();
        for key in keys {
            let idx = self.map[&key];
            if self.frames[idx as usize].dirty {
                self.unlink(idx);
                self.map.remove(&key);
                let frame = &mut self.frames[idx as usize];
                let data = std::mem::replace(&mut frame.data, Box::from(&[][..]));
                self.free_slots.push(idx);
                self.stats.evictions_dirty += 1;
                out.push(EvictedPage { key, data, dirty: true });
            }
        }
        out.sort_by_key(|e| e.key);
        out
    }

    /// LRU order of resident keys, most recent first (testing / debugging).
    pub fn lru_order(&self) -> Vec<PageKey> {
        let mut out = Vec::with_capacity(self.map.len());
        let mut idx = self.head;
        while idx != NIL {
            out.push(self.frames[idx as usize].key);
            idx = self.frames[idx as usize].next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf(pages: usize) -> PageBuffer {
        PageBuffer::new(pages as u64 * 4096, 4096, 1.0)
    }

    fn buf_lru(pages: usize) -> PageBuffer {
        PageBuffer::with_policy(pages as u64 * 4096, 4096, 1.0, EvictPolicy::AccessLru)
    }

    fn k(p: u64) -> PageKey {
        PageKey::new(1, p)
    }

    #[test]
    fn insert_then_access_hits() {
        let mut b = buf(4);
        b.insert_with(k(0), false, |d| d[0] = 42);
        let d = b.access(k(0), false).expect("resident");
        assert_eq!(d[0], 42);
        let s = b.stats();
        assert_eq!((s.hits, s.misses), (1, 0));
    }

    #[test]
    fn miss_counts() {
        let mut b = buf(4);
        assert!(b.access(k(9), false).is_none());
        assert_eq!(b.stats().misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut b = buf_lru(3);
        for p in 0..3 {
            b.insert_with(k(p), false, |_| {});
        }
        // Touch page 0 so page 1 becomes LRU.
        b.access(k(0), false);
        let ev = b.evict_lru().unwrap();
        assert_eq!(ev.key, k(1));
        assert!(!ev.dirty);
    }

    #[test]
    fn fault_fifo_ignores_hits() {
        // Default policy: a hit must NOT refresh recency (uffd cannot see
        // accesses), so the hot page 0 is still evicted first.
        let mut b = buf(3);
        for p in 0..3 {
            b.insert_with(k(p), false, |_| {});
        }
        b.access(k(0), false); // hot, but invisible to the manager
        let ev = b.evict_lru().unwrap();
        assert_eq!(ev.key, k(0), "fault-FIFO evicts by fault order");
        assert_eq!(b.policy(), EvictPolicy::FaultFifo);
    }

    #[test]
    fn dirty_propagates_to_eviction() {
        let mut b = buf(2);
        b.insert_with(k(0), false, |_| {});
        b.access(k(0), true); // write marks dirty
        b.insert_with(k(1), false, |_| {});
        b.access(k(1), false);
        let ev = b.evict_lru().unwrap(); // page 0 is LRU
        assert_eq!(ev.key, k(0));
        assert!(ev.dirty);
        assert_eq!(b.stats().evictions_dirty, 1);
    }

    #[test]
    fn eviction_frees_capacity_and_data_survives() {
        let mut b = buf(2);
        b.insert_with(k(0), true, |d| d.fill(7));
        b.insert_with(k(1), false, |_| {});
        assert!(b.is_full());
        let ev = b.evict_lru().unwrap();
        assert!(ev.data.iter().all(|&x| x == 7), "evicted data intact");
        assert!(!b.is_full());
        b.recycle(ev.data);
        b.insert_with(k(2), false, |_| {});
        assert!(b.is_resident(k(2)));
        assert!(!b.is_resident(k(0)));
    }

    #[test]
    fn proactive_threshold() {
        let mut b = PageBuffer::new(10 * 4096, 4096, 0.8);
        for p in 0..7 {
            b.insert_with(k(p), false, |_| {});
        }
        assert!(!b.over_threshold()); // 8th insert ok: 8 <= 0.8*10
        b.insert_with(k(7), false, |_| {});
        assert!(b.over_threshold()); // 9th insert would exceed
    }

    #[test]
    fn unified_across_regions() {
        let mut b = buf(4);
        b.insert_with(PageKey::new(1, 0), false, |_| {});
        b.insert_with(PageKey::new(2, 0), false, |_| {});
        assert_eq!(b.resident_pages(), 2);
        assert!(b.is_resident(PageKey::new(1, 0)));
        assert!(b.is_resident(PageKey::new(2, 0)));
        // Same page index, different region — distinct keys.
        assert!(!b.is_resident(PageKey::new(3, 0)));
    }

    #[test]
    fn drain_dirty_returns_only_dirty_sorted() {
        let mut b = buf(8);
        for p in 0..6 {
            b.insert_with(k(p), p % 2 == 0, |_| {});
        }
        let drained = b.drain_dirty();
        let keys: Vec<u64> = drained.iter().map(|e| e.key.page).collect();
        assert_eq!(keys, vec![0, 2, 4]);
        assert_eq!(b.resident_pages(), 3);
    }

    #[test]
    fn lru_order_reflects_touches() {
        let mut b = buf_lru(4);
        for p in 0..3 {
            b.insert_with(k(p), false, |_| {});
        }
        b.access(k(0), false);
        assert_eq!(b.lru_order(), vec![k(0), k(2), k(1)]);
    }

    #[test]
    fn reinsert_after_evict() {
        let mut b = buf(1);
        b.insert_with(k(0), false, |d| d[0] = 1);
        let ev = b.evict_lru().unwrap();
        b.recycle(ev.data);
        b.insert_with(k(0), false, |d| d[0] = 2);
        assert_eq!(b.access(k(0), false).unwrap()[0], 2);
    }

    #[test]
    #[should_panic(expected = "already resident")]
    fn double_insert_panics() {
        let mut b = buf(2);
        b.insert_with(k(0), false, |_| {});
        b.insert_with(k(0), false, |_| {});
    }

    #[test]
    fn hit_rate() {
        let mut b = buf(2);
        b.insert_with(k(0), false, |_| {});
        b.access(k(0), false);
        b.access(k(1), false);
        assert!((b.stats().hit_rate() - 0.5).abs() < 1e-9);
    }
}
