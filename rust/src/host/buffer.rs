//! The host agent's unified page buffer (§III).
//!
//! One buffer is shared by *all* FAM-backed objects and managed in
//! equal-sized data chunks (64 KB on the testbed), "to ensure the local
//! buffer is distributed to FAM-backed objects as needed". Dirty chunks are
//! written back on eviction; a *proactive eviction policy* triggers when
//! the buffer reaches a threshold load factor so that evictions stay off
//! the fault critical path.
//!
//! Implementation: this type is the frame-storage *shell* of the unified
//! cache subsystem ([`crate::cache`]). It owns the fixed frame pool, the
//! residency hash map, dirty bits and the recycled-storage free lists; all
//! ordering and victim selection is delegated to a pluggable
//! [`ReplacementPolicy`] engine selected by [`EvictPolicy`] (see
//! `SodaConfig::evict_policy` / `soda run --evict-policy`). No allocation
//! happens on the steady-state fault path — evicted frames donate their
//! storage to the incoming page.
//!
//! The default policy is [`EvictPolicy::FaultFifo`]: the paper's buffer is
//! managed through `userfaultfd`, which only observes page *faults* — once
//! a chunk is mapped, later accesses are invisible to the runtime, so "LRU"
//! means least-recently-FAULTED, and hot pages churn once the buffer turns
//! over (the access-density effect that makes DPU static caching pay off,
//! Fig 9). Its eviction order is bit-identical to the pre-subsystem
//! implementation. [`EvictPolicy::AccessLru`] is the idealized policy (as
//! if access bits were free); `Clock`, `SegmentedLru` and `Random` complete
//! the ablation space.
//!
//! ## Sharding (lock-free hit path)
//!
//! The residency table is split into P shards keyed by a `PageKey` hash
//! (see [`PageBuffer::set_shards`]): each shard owns its slice of the
//! residency map, its own replacement engine and its own deterministic RNG,
//! so concurrent host workers contend only on the shard their fault hashes
//! to — and the hit path never enters a shard's slow path at all, because
//! per-frame dirty/pin/generation state lives in a packed
//! [`FrameState`](crate::host::frame_state::FrameState) atomic word
//! (pin/unpin/mark-dirty are single atomic ops). The shard hash buckets
//! *aligned 16-page runs*, not single pages, so the coalesced spans the
//! batched fault engine produces stay shard-local instead of scattering one
//! range request across P miss queues.
//!
//! Global eviction order is preserved across shards by a per-frame stamp
//! (monotone event counter): victim selection peeks every shard's candidate
//! ([`ReplacementPolicy::peek_victim`], non-mutating) and evicts the
//! globally coldest stamp, which reproduces the exact single-shard
//! `FaultFifo`/`AccessLru` order at any P. Policies with stateful victim
//! choice (`Random`'s probes, `Clock`'s sweep) cannot be peeked; those fall
//! back to a deterministic round-robin shard rotation — still reproducible,
//! but a documented divergence from the P=1 stream. With one shard (the
//! default) every path reduces bit-identically to the pre-shard shell.

use crate::cache::ReplacementPolicy;
use crate::host::frame_state::FrameState;
use crate::memnode::RegionId;
use crate::sim::rng::Rng;
use crate::util::fxhash::FxHashMap;

/// Eviction policy of the unified buffer — an alias for the cache
/// subsystem's [`PolicyKind`](crate::cache::PolicyKind), kept under the
/// historical name so existing call sites (`EvictPolicy::FaultFifo`, …)
/// read unchanged.
pub use crate::cache::PolicyKind as EvictPolicy;

/// Identity of one page (chunk) of a FAM region.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PageKey {
    pub region: RegionId,
    /// Page index within the region (page_offset / chunk_bytes).
    pub page: u64,
}

impl PageKey {
    pub fn new(region: RegionId, page: u64) -> Self {
        PageKey { region, page }
    }

    /// Byte offset of this page within its region.
    pub fn byte_offset(&self, chunk_bytes: u64) -> u64 {
        self.page * chunk_bytes
    }
}

/// A run of `pages` contiguous pages starting at `start` — the unit of the
/// batched fault engine's coalesced range requests (§III task aggregation:
/// contiguous misses travel as one multi-page request, so a k-page burst
/// pays one request descriptor and one wire message instead of k).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageSpan {
    pub start: PageKey,
    pub pages: u64,
}

impl PageSpan {
    pub fn single(key: PageKey) -> Self {
        PageSpan { start: key, pages: 1 }
    }

    /// The `i`-th page of the span.
    pub fn key_at(&self, i: u64) -> PageKey {
        debug_assert!(i < self.pages);
        PageKey::new(self.start.region, self.start.page + i)
    }

    /// Total payload bytes of the span.
    pub fn bytes(&self, chunk_bytes: u64) -> u64 {
        self.pages * chunk_bytes
    }

    /// Byte offset of the span within its region.
    pub fn byte_offset(&self, chunk_bytes: u64) -> u64 {
        self.start.byte_offset(chunk_bytes)
    }

    /// Group an ordered key list into spans. With `merge`, a key that
    /// directly follows the previous span's last page (same region) extends
    /// that span; otherwise every key becomes a singleton span. Order is
    /// preserved, so the flattened span pages enumerate `keys` exactly.
    pub fn coalesce(keys: &[PageKey], merge: bool) -> Vec<PageSpan> {
        let mut out: Vec<PageSpan> = Vec::new();
        for &k in keys {
            if merge {
                if let Some(last) = out.last_mut() {
                    if last.start.region == k.region && k.page == last.start.page + last.pages {
                        last.pages += 1;
                        continue;
                    }
                }
            }
            out.push(PageSpan::single(k));
        }
        out
    }
}

#[derive(Debug)]
struct Frame {
    key: PageKey,
    data: Box<[u8]>,
    /// Packed atomic dirty bit / pin count / residency generation — the
    /// lock-free hit-path word (see [`crate::host::frame_state`]).
    state: FrameState,
    /// Global eviction-order stamp: monotone event counter assigned at
    /// insert (and refreshed on touch for recency policies), merged across
    /// shards to reconstruct the exact single-shard victim order.
    stamp: u64,
}

/// One residency shard: its slice of the page table plus a private
/// replacement engine and RNG (stochastic policies stay deterministic
/// per-shard).
#[derive(Debug)]
struct Shard {
    map: FxHashMap<PageKey, u32>,
    engine: Box<dyn ReplacementPolicy>,
    rng: Rng,
}

/// Shard index of `key` among `shards` buckets. Hashes the *aligned
/// 16-page run* (`page >> 4`), not the page, so contiguous coalesced spans
/// land in one shard. The host agent reuses the same function to assign
/// miss spans to worker lanes, keeping a page's lane and shard aligned.
pub(crate) fn shard_index(key: PageKey, shards: usize) -> usize {
    if shards <= 1 {
        return 0;
    }
    let mut h = (key.region as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h ^= (key.page >> 4).wrapping_mul(0xD1B5_4A32_D192_ED03);
    h ^= h >> 32;
    h as usize % shards
}

/// A page evicted from the buffer; `dirty` means it must be written back.
#[derive(Debug)]
pub struct EvictedPage {
    pub key: PageKey,
    pub data: Box<[u8]>,
    pub dirty: bool,
}

/// Buffer statistics for the host agent's metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct BufferStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions_clean: u64,
    pub evictions_dirty: u64,
}

impl BufferStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Unified page buffer: frame storage shell over P residency shards, each
/// with its own pluggable replacement engine.
#[derive(Debug)]
pub struct PageBuffer {
    chunk_bytes: u64,
    frames: Vec<Frame>,
    /// Residency shards (page table slices + per-shard engines). One shard
    /// by default — bit-identical to the pre-shard unified table.
    shards: Vec<Shard>,
    /// Per-slot residency bit (`slot` currently holds a live page) — part
    /// of the `evictable` predicate handed to the engines.
    resident_slots: Vec<bool>,
    /// Reusable storage from freed frames.
    spare: Vec<Box<[u8]>>,
    /// Frame slots vacated by eviction, reusable by the next insert.
    free_slots: Vec<u32>,
    capacity_pages: usize,
    /// Proactive-eviction trigger: load factor above which the agent starts
    /// evicting ahead of demand (§III, "triggered when the buffer reaches a
    /// threshold load factor").
    load_threshold: f64,
    stats: BufferStats,
    /// Selected policy kind (rebuilt per shard by [`Self::set_shards`]).
    policy: EvictPolicy,
    /// Base RNG seed, re-derived per shard.
    seed: u64,
    /// Monotone event counter feeding the per-frame eviction-order stamps.
    tick: u64,
    /// Total resident pages across shards (O(1) load-factor checks).
    resident: usize,
    /// Round-robin shard rotation for policies without `peek_victim`.
    shard_cursor: usize,
}

impl PageBuffer {
    /// Default seed for stochastic policies when no cluster seed is
    /// threaded through (direct construction in tests/benches).
    pub const DEFAULT_RNG_SEED: u64 = 0x50DA_0CAC;

    pub fn new(capacity_bytes: u64, chunk_bytes: u64, load_threshold: f64) -> Self {
        Self::with_policy(capacity_bytes, chunk_bytes, load_threshold, EvictPolicy::FaultFifo)
    }

    pub fn with_policy(
        capacity_bytes: u64,
        chunk_bytes: u64,
        load_threshold: f64,
        policy: EvictPolicy,
    ) -> Self {
        Self::with_policy_seeded(
            capacity_bytes,
            chunk_bytes,
            load_threshold,
            policy,
            Self::DEFAULT_RNG_SEED,
        )
    }

    /// Like [`Self::with_policy`] with an explicit RNG seed for stochastic
    /// policies — the service threads `ClusterConfig::seed` through here so
    /// "deterministic seed for all stochastic components" holds for random
    /// buffer eviction too (seed sweeps produce independent trials).
    pub fn with_policy_seeded(
        capacity_bytes: u64,
        chunk_bytes: u64,
        load_threshold: f64,
        policy: EvictPolicy,
        seed: u64,
    ) -> Self {
        assert!(chunk_bytes > 0 && chunk_bytes.is_power_of_two());
        assert!((0.0..=1.0).contains(&load_threshold));
        let capacity_pages = (capacity_bytes / chunk_bytes).max(1) as usize;
        let mut buf = PageBuffer {
            chunk_bytes,
            frames: Vec::with_capacity(capacity_pages.min(1 << 20)),
            shards: Vec::new(),
            resident_slots: Vec::new(),
            spare: Vec::new(),
            free_slots: Vec::new(),
            capacity_pages,
            load_threshold,
            stats: BufferStats::default(),
            policy,
            seed,
            tick: 0,
            resident: 0,
            shard_cursor: 0,
        };
        buf.set_shards(1);
        buf
    }

    /// Re-partition the residency table into `shards` shards. Must be
    /// called while the buffer is empty (the service applies it at client
    /// construction, before any page lands). Shard 0 keeps the exact
    /// single-shard RNG stream; further shards derive independent streams.
    pub fn set_shards(&mut self, shards: usize) {
        assert!(shards >= 1, "at least one shard");
        assert_eq!(self.resident, 0, "set_shards on a non-empty buffer");
        let base = self.seed ^ self.capacity_pages as u64;
        self.shards = (0..shards)
            .map(|i| Shard {
                map: FxHashMap::default(),
                engine: self.policy.build(self.capacity_pages),
                rng: Rng::new(base ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            })
            .collect();
        self.shard_cursor = 0;
    }

    /// Number of residency shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    fn shard_of(&self, key: PageKey) -> usize {
        shard_index(key, self.shards.len())
    }

    pub fn policy(&self) -> EvictPolicy {
        self.policy
    }

    pub fn chunk_bytes(&self) -> u64 {
        self.chunk_bytes
    }

    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }

    pub fn resident_pages(&self) -> usize {
        self.resident
    }

    pub fn load_factor(&self) -> f64 {
        self.resident as f64 / self.capacity_pages as f64
    }

    pub fn stats(&self) -> BufferStats {
        self.stats
    }

    pub fn is_resident(&self, key: PageKey) -> bool {
        self.shards[self.shard_of(key)].map.contains_key(&key)
    }

    /// Look up a page; on hit, the shard's replacement engine is notified
    /// (e.g. `AccessLru` refreshes recency; `FaultFifo` cannot see hits, so
    /// its order is untouched) and the data is returned. `write` marks the
    /// frame dirty (one atomic `fetch_or` on the frame's state word — no
    /// shard-table mutation on the hit path). Counts hit/miss.
    pub fn access(&mut self, key: PageKey, write: bool) -> Option<&mut [u8]> {
        let si = self.shard_of(key);
        match self.shards[si].map.get(&key).copied() {
            Some(idx) => {
                self.stats.hits += 1;
                self.shards[si].engine.on_touch(idx);
                // Recency policies refresh the cross-shard stamp on touch
                // so the global merge tracks true access order; FaultFifo
                // keeps its fault-time stamp (hits are invisible to uffd).
                if self.policy != EvictPolicy::FaultFifo {
                    self.tick += 1;
                    self.frames[idx as usize].stamp = self.tick;
                }
                let f = &mut self.frames[idx as usize];
                if write {
                    f.state.set_dirty();
                }
                Some(&mut f.data)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Non-counting residency probe returning the data if present (used by
    /// multi-page copies after an explicit fault).
    pub fn peek(&mut self, key: PageKey) -> Option<&mut [u8]> {
        let si = self.shard_of(key);
        let idx = self.shards[si].map.get(&key).copied()?;
        Some(&mut self.frames[idx as usize].data)
    }

    /// Pin a resident page (fetch/fill in flight): the frame is excluded
    /// from victim selection until unpinned. One atomic CAS on the frame's
    /// state word. Returns `false` if the page is not resident.
    pub fn pin(&mut self, key: PageKey) -> bool {
        let si = self.shard_of(key);
        match self.shards[si].map.get(&key).copied() {
            Some(idx) => {
                self.frames[idx as usize]
                    .state
                    .pin()
                    .expect("pin count saturated");
                self.shards[si].engine.on_pin(idx);
                true
            }
            None => false,
        }
    }

    /// Drop a pin acquired by [`Self::pin`]. Returns `false` if the page is
    /// not resident.
    pub fn unpin(&mut self, key: PageKey) -> bool {
        let si = self.shard_of(key);
        match self.shards[si].map.get(&key).copied() {
            Some(idx) => {
                self.frames[idx as usize].state.unpin();
                self.shards[si].engine.on_unpin(idx);
                true
            }
            None => false,
        }
    }

    /// Residency generation of a resident page's frame (the writeback ABA
    /// handshake token — see [`crate::host::frame_state`]).
    pub fn generation(&self, key: PageKey) -> Option<u64> {
        let si = self.shard_of(key);
        let idx = self.shards[si].map.get(&key).copied()?;
        Some(self.frames[idx as usize].state.generation())
    }

    /// True if inserting one more page should be preceded by eviction(s)
    /// under the proactive policy.
    pub fn over_threshold(&self) -> bool {
        (self.resident + 1) as f64 > self.load_threshold * self.capacity_pages as f64
    }

    /// True if the buffer is completely full (demand eviction required).
    pub fn is_full(&self) -> bool {
        self.resident >= self.capacity_pages
    }

    /// Evict the globally coldest victim, returning it for potential
    /// writeback. Demand eviction must succeed, so if a stochastic engine's
    /// bounded probes come up empty the shell falls back to the lowest
    /// resident unpinned slot (on the default path no page is ever pinned,
    /// so some victim always exists).
    ///
    /// With one shard this is exactly the engine's own victim choice. With
    /// P shards, peekable policies merge per-shard candidates by their
    /// eviction-order stamp (exact single-shard `FaultFifo`/`AccessLru`
    /// order); non-peekable ones (`Random`, `Clock`) rotate round-robin
    /// across shards — deterministic, but a different stream than P=1.
    pub fn evict_victim(&mut self) -> Option<EvictedPage> {
        let (si, idx) = self.pick_victim()?;
        Some(self.remove_frame(si, idx))
    }

    fn pick_victim(&mut self) -> Option<(usize, u32)> {
        let PageBuffer {
            shards,
            frames,
            resident_slots,
            shard_cursor,
            ..
        } = &mut *self;
        let evictable = |slot: u32| {
            resident_slots.get(slot as usize).copied().unwrap_or(false)
                && frames
                    .get(slot as usize)
                    .map(|f| f.state.is_evictable())
                    .unwrap_or(false)
        };
        if shards.len() == 1 {
            let shard = &mut shards[0];
            return shard
                .engine
                .victim(&mut shard.rng, &evictable)
                .or_else(|| {
                    resident_slots
                        .iter()
                        .position(|&r| r)
                        .filter(|&i| evictable(i as u32))
                        .map(|i| i as u32)
                })
                .map(|idx| (0, idx));
        }
        // Stamp-merged peek: every shard offers its would-be victim without
        // mutating; the globally coldest stamp wins and only that shard's
        // engine is disturbed (by the on_remove in remove_frame).
        let mut best: Option<(usize, u32, u64)> = None;
        for (si, shard) in shards.iter().enumerate() {
            if let Some(slot) = shard.engine.peek_victim(&evictable) {
                let stamp = frames[slot as usize].stamp;
                if best.is_none_or(|(_, _, b)| stamp < b) {
                    best = Some((si, slot, stamp));
                }
            }
        }
        if let Some((si, slot, _)) = best {
            return Some((si, slot));
        }
        // Non-peekable policies: deterministic round-robin shard rotation.
        let p = shards.len();
        for i in 0..p {
            let si = (*shard_cursor + i) % p;
            let shard = &mut shards[si];
            if shard.engine.is_empty() {
                continue;
            }
            if let Some(slot) = shard.engine.victim(&mut shard.rng, &evictable) {
                *shard_cursor = (si + 1) % p;
                return Some((si, slot));
            }
        }
        // Last-resort scan (mirrors the single-shard shell fallback).
        let idx = resident_slots
            .iter()
            .position(|&r| r)
            .filter(|&i| evictable(i as u32))? as u32;
        let si = shard_index(frames[idx as usize].key, p);
        Some((si, idx))
    }

    fn remove_frame(&mut self, si: usize, idx: u32) -> EvictedPage {
        self.shards[si].engine.on_remove(idx);
        self.resident_slots[idx as usize] = false;
        let frame = &mut self.frames[idx as usize];
        let key = frame.key;
        let dirty = frame.state.is_dirty();
        // Donate a fresh empty box and steal the data.
        let data = std::mem::replace(&mut frame.data, Box::from(&[][..]));
        self.shards[si].map.remove(&key);
        self.resident -= 1;
        self.free_slots.push(idx);
        if dirty {
            self.stats.evictions_dirty += 1;
        } else {
            self.stats.evictions_clean += 1;
        }
        EvictedPage { key, data, dirty }
    }

    /// Historical name for [`Self::evict_victim`] (the default policy's
    /// victim *is* the least-recently-faulted page).
    pub fn evict_lru(&mut self) -> Option<EvictedPage> {
        self.evict_victim()
    }

    /// Insert a page (must not be resident; caller evicts first if full).
    /// `fill` populates the frame's storage. Returns a mutable view.
    pub fn insert_with(
        &mut self,
        key: PageKey,
        dirty: bool,
        fill: impl FnOnce(&mut [u8]),
    ) -> &mut [u8] {
        let si = self.shard_of(key);
        assert!(
            !self.shards[si].map.contains_key(&key),
            "page already resident: {key:?}"
        );
        assert!(
            self.resident < self.capacity_pages,
            "buffer full; evict before insert"
        );
        let idx = if let Some(idx) = self.free_slots.pop() {
            let data = self
                .spare
                .pop()
                .unwrap_or_else(|| vec![0u8; self.chunk_bytes as usize].into_boxed_slice());
            let f = &mut self.frames[idx as usize];
            f.key = key;
            f.data = data;
            // Reoccupation bumps the residency generation (the writeback
            // ABA guard) and installs the fresh dirty bit.
            f.state.reinsert(dirty);
            idx
        } else {
            let idx = self.frames.len() as u32;
            self.frames.push(Frame {
                key,
                data: vec![0u8; self.chunk_bytes as usize].into_boxed_slice(),
                state: FrameState::new(dirty),
                stamp: 0,
            });
            idx
        };
        self.tick += 1;
        self.frames[idx as usize].stamp = self.tick;
        if self.resident_slots.len() <= idx as usize {
            self.resident_slots.resize(idx as usize + 1, false);
        }
        self.resident_slots[idx as usize] = true;
        self.shards[si].engine.on_insert(idx);
        self.shards[si].map.insert(key, idx);
        self.resident += 1;
        let f = &mut self.frames[idx as usize];
        fill(&mut f.data);
        &mut f.data
    }

    /// Return spare storage (an evicted page's buffer after writeback) so
    /// the steady state allocates nothing.
    pub fn recycle(&mut self, data: Box<[u8]>) {
        if data.len() == self.chunk_bytes as usize {
            self.spare.push(data);
        }
    }

    /// Drain every resident dirty page (flush at deallocation / barrier).
    /// Output is key-sorted, so the result is shard-count independent.
    pub fn drain_dirty(&mut self) -> Vec<EvictedPage> {
        let mut out = Vec::new();
        for si in 0..self.shards.len() {
            let keys: Vec<PageKey> = self.shards[si].map.keys().copied().collect();
            for key in keys {
                let idx = self.shards[si].map[&key];
                if self.frames[idx as usize].state.is_dirty() {
                    out.push(self.remove_frame(si, idx));
                }
            }
        }
        out.sort_by_key(|e| e.key);
        out
    }

    /// Resident keys in the engines' protection order, most protected
    /// first (for `FaultFifo`/`AccessLru` at one shard exactly MRU→LRU;
    /// with P shards, shard 0's order first, then shard 1's, …; testing
    /// and debugging).
    pub fn lru_order(&self) -> Vec<PageKey> {
        self.shards
            .iter()
            .flat_map(|s| s.engine.order())
            .map(|idx| self.frames[idx as usize].key)
            .collect()
    }

    /// Demote a resident page hard in its shard's engine (hint-aware
    /// eviction: a speculative page whose superstep expired untouched
    /// becomes the shard's preferred next victim).
    pub fn demote(&mut self, key: PageKey) -> bool {
        let si = self.shard_of(key);
        match self.shards[si].map.get(&key).copied() {
            Some(idx) => {
                self.shards[si].engine.on_demote(idx);
                // The stamp moves to the cold extreme so the cross-shard
                // merge also prefers it.
                self.frames[idx as usize].stamp = 0;
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf(pages: usize) -> PageBuffer {
        PageBuffer::new(pages as u64 * 4096, 4096, 1.0)
    }

    fn buf_lru(pages: usize) -> PageBuffer {
        PageBuffer::with_policy(pages as u64 * 4096, 4096, 1.0, EvictPolicy::AccessLru)
    }

    fn k(p: u64) -> PageKey {
        PageKey::new(1, p)
    }

    #[test]
    fn insert_then_access_hits() {
        let mut b = buf(4);
        b.insert_with(k(0), false, |d| d[0] = 42);
        let d = b.access(k(0), false).expect("resident");
        assert_eq!(d[0], 42);
        let s = b.stats();
        assert_eq!((s.hits, s.misses), (1, 0));
    }

    #[test]
    fn miss_counts() {
        let mut b = buf(4);
        assert!(b.access(k(9), false).is_none());
        assert_eq!(b.stats().misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut b = buf_lru(3);
        for p in 0..3 {
            b.insert_with(k(p), false, |_| {});
        }
        // Touch page 0 so page 1 becomes LRU.
        b.access(k(0), false);
        let ev = b.evict_lru().unwrap();
        assert_eq!(ev.key, k(1));
        assert!(!ev.dirty);
    }

    #[test]
    fn fault_fifo_ignores_hits() {
        // Default policy: a hit must NOT refresh recency (uffd cannot see
        // accesses), so the hot page 0 is still evicted first.
        let mut b = buf(3);
        for p in 0..3 {
            b.insert_with(k(p), false, |_| {});
        }
        b.access(k(0), false); // hot, but invisible to the manager
        let ev = b.evict_lru().unwrap();
        assert_eq!(ev.key, k(0), "fault-FIFO evicts by fault order");
        assert_eq!(b.policy(), EvictPolicy::FaultFifo);
    }

    #[test]
    fn dirty_propagates_to_eviction() {
        let mut b = buf(2);
        b.insert_with(k(0), false, |_| {});
        b.access(k(0), true); // write marks dirty
        b.insert_with(k(1), false, |_| {});
        b.access(k(1), false);
        let ev = b.evict_lru().unwrap(); // page 0 is LRU
        assert_eq!(ev.key, k(0));
        assert!(ev.dirty);
        assert_eq!(b.stats().evictions_dirty, 1);
    }

    #[test]
    fn eviction_frees_capacity_and_data_survives() {
        let mut b = buf(2);
        b.insert_with(k(0), true, |d| d.fill(7));
        b.insert_with(k(1), false, |_| {});
        assert!(b.is_full());
        let ev = b.evict_lru().unwrap();
        assert!(ev.data.iter().all(|&x| x == 7), "evicted data intact");
        assert!(!b.is_full());
        b.recycle(ev.data);
        b.insert_with(k(2), false, |_| {});
        assert!(b.is_resident(k(2)));
        assert!(!b.is_resident(k(0)));
    }

    #[test]
    fn proactive_threshold() {
        let mut b = PageBuffer::new(10 * 4096, 4096, 0.8);
        for p in 0..7 {
            b.insert_with(k(p), false, |_| {});
        }
        assert!(!b.over_threshold()); // 8th insert ok: 8 <= 0.8*10
        b.insert_with(k(7), false, |_| {});
        assert!(b.over_threshold()); // 9th insert would exceed
    }

    #[test]
    fn unified_across_regions() {
        let mut b = buf(4);
        b.insert_with(PageKey::new(1, 0), false, |_| {});
        b.insert_with(PageKey::new(2, 0), false, |_| {});
        assert_eq!(b.resident_pages(), 2);
        assert!(b.is_resident(PageKey::new(1, 0)));
        assert!(b.is_resident(PageKey::new(2, 0)));
        // Same page index, different region — distinct keys.
        assert!(!b.is_resident(PageKey::new(3, 0)));
    }

    #[test]
    fn drain_dirty_returns_only_dirty_sorted() {
        let mut b = buf(8);
        for p in 0..6 {
            b.insert_with(k(p), p % 2 == 0, |_| {});
        }
        let drained = b.drain_dirty();
        let keys: Vec<u64> = drained.iter().map(|e| e.key.page).collect();
        assert_eq!(keys, vec![0, 2, 4]);
        assert_eq!(b.resident_pages(), 3);
    }

    #[test]
    fn lru_order_reflects_touches() {
        let mut b = buf_lru(4);
        for p in 0..3 {
            b.insert_with(k(p), false, |_| {});
        }
        b.access(k(0), false);
        assert_eq!(b.lru_order(), vec![k(0), k(2), k(1)]);
    }

    #[test]
    fn reinsert_after_evict() {
        let mut b = buf(1);
        b.insert_with(k(0), false, |d| d[0] = 1);
        let ev = b.evict_lru().unwrap();
        b.recycle(ev.data);
        b.insert_with(k(0), false, |d| d[0] = 2);
        assert_eq!(b.access(k(0), false).unwrap()[0], 2);
    }

    #[test]
    #[should_panic(expected = "already resident")]
    fn double_insert_panics() {
        let mut b = buf(2);
        b.insert_with(k(0), false, |_| {});
        b.insert_with(k(0), false, |_| {});
    }

    #[test]
    fn hit_rate() {
        let mut b = buf(2);
        b.insert_with(k(0), false, |_| {});
        b.access(k(0), false);
        b.access(k(1), false);
        assert!((b.stats().hit_rate() - 0.5).abs() < 1e-9);
    }

    // ---- pluggable-policy coverage -------------------------------------

    /// Every policy keeps the residency map and its tracked order in sync
    /// under a mixed insert/touch/evict workload.
    #[test]
    fn order_matches_residency_for_all_policies() {
        for policy in EvictPolicy::ALL {
            let mut b = PageBuffer::with_policy(8 * 4096, 4096, 1.0, policy);
            for p in 0..8 {
                b.insert_with(k(p), p % 3 == 0, |_| {});
            }
            b.access(k(1), false);
            b.access(k(4), true);
            for _ in 0..3 {
                let ev = b.evict_victim().expect("resident pages remain");
                b.recycle(ev.data);
            }
            b.insert_with(k(100), false, |_| {});
            let mut order: Vec<PageKey> = b.lru_order();
            order.sort();
            let mut resident: Vec<PageKey> = (0..8)
                .map(k)
                .chain(std::iter::once(k(100)))
                .filter(|&key| b.is_resident(key))
                .collect();
            resident.sort();
            assert_eq!(order, resident, "{policy:?}: engine order vs residency map");
            assert_eq!(b.resident_pages(), order.len(), "{policy:?}");
        }
    }

    #[test]
    fn clock_gives_touched_page_a_second_chance() {
        let mut b = PageBuffer::with_policy(3 * 4096, 4096, 1.0, EvictPolicy::Clock);
        for p in 0..3 {
            b.insert_with(k(p), false, |_| {});
        }
        b.access(k(0), false); // reference bit set on the oldest page
        let ev = b.evict_victim().unwrap();
        assert_eq!(ev.key, k(1), "clock skips the referenced page once");
    }

    #[test]
    fn slru_protects_rereferenced_pages_from_scans() {
        let mut b = PageBuffer::with_policy(4 * 4096, 4096, 1.0, EvictPolicy::SegmentedLru);
        b.insert_with(k(0), false, |_| {});
        b.access(k(0), false); // promoted to the protected segment
        for p in 1..4 {
            b.insert_with(k(p), false, |_| {});
        }
        // A scan of one-hit wonders must drain probation before touching
        // the protected page.
        for _ in 0..3 {
            let ev = b.evict_victim().unwrap();
            assert_ne!(ev.key, k(0), "protected page evicted by a scan");
            b.recycle(ev.data);
        }
        assert!(b.is_resident(k(0)));
    }

    #[test]
    fn random_policy_seed_reproduces_and_varies_eviction_streams() {
        let evictions = |seed: u64| -> Vec<u64> {
            let mut b = PageBuffer::with_policy_seeded(
                8 * 4096,
                4096,
                1.0,
                EvictPolicy::Random,
                seed,
            );
            let mut out = Vec::new();
            for p in 0..64u64 {
                if b.access(k(p % 24), false).is_none() {
                    while b.is_full() {
                        let ev = b.evict_victim().unwrap();
                        out.push(ev.key.page);
                        b.recycle(ev.data);
                    }
                    b.insert_with(k(p % 24), false, |_| {});
                }
            }
            out
        };
        assert_eq!(evictions(1), evictions(1), "same seed → identical stream");
        assert_ne!(
            evictions(1),
            evictions(2),
            "different cluster seeds must give independent random-eviction trials"
        );
    }

    // ---- sharded residency table ---------------------------------------

    /// Mixed insert/touch/evict workload driven identically at 1 and P
    /// shards: for the peekable policies the eviction *stream* (not just
    /// the final set) must be bit-identical — the stamp merge reconstructs
    /// the exact global order.
    #[test]
    fn shard_merge_preserves_global_eviction_order() {
        for policy in [EvictPolicy::FaultFifo, EvictPolicy::AccessLru] {
            let run = |shards: usize| -> (Vec<PageKey>, Vec<PageKey>) {
                let mut b = PageBuffer::with_policy(6 * 4096, 4096, 1.0, policy);
                b.set_shards(shards);
                let mut evicted = Vec::new();
                for p in 0..48u64 {
                    let key = k(p * 37 % 19); // scattered across shards
                    if b.access(key, p % 5 == 0).is_none() {
                        while b.is_full() {
                            let ev = b.evict_victim().unwrap();
                            evicted.push(ev.key);
                            b.recycle(ev.data);
                        }
                        b.insert_with(key, false, |_| {});
                    }
                }
                let mut resident: Vec<PageKey> = (0..19).map(k).filter(|&x| b.is_resident(x)).collect();
                resident.sort();
                (evicted, resident)
            };
            let (ev1, res1) = run(1);
            for shards in [2usize, 3, 8] {
                let (evp, resp) = run(shards);
                assert_eq!(ev1, evp, "{policy:?} @ {shards} shards: eviction stream diverged");
                assert_eq!(res1, resp, "{policy:?} @ {shards} shards: residency diverged");
            }
        }
    }

    #[test]
    fn sharded_coalesced_runs_stay_shard_local() {
        let mut b = buf(64);
        b.set_shards(8);
        // A 16-page aligned run hashes to one shard: evicting in pure
        // FaultFifo order must walk the run in insertion order even though
        // other shards hold interleaved pages.
        for p in 0..16u64 {
            b.insert_with(k(p), false, |_| {});
        }
        for p in 0..16u64 {
            let ev = b.evict_victim().unwrap();
            assert_eq!(ev.key, k(p));
            b.recycle(ev.data);
        }
    }

    #[test]
    fn shard_count_is_transparent_to_dirty_tracking() {
        let mut b = buf(8);
        b.set_shards(4);
        for p in 0..6 {
            b.insert_with(k(p), p % 2 == 0, |_| {});
        }
        b.access(k(1), true); // write hit dirties via the atomic word
        let drained: Vec<u64> = b.drain_dirty().iter().map(|e| e.key.page).collect();
        assert_eq!(drained, vec![0, 1, 2, 4]);
        assert_eq!(b.resident_pages(), 2);
    }

    #[test]
    #[should_panic(expected = "set_shards on a non-empty buffer")]
    fn set_shards_requires_empty_buffer() {
        let mut b = buf(4);
        b.insert_with(k(0), false, |_| {});
        b.set_shards(2);
    }

    #[test]
    fn random_policy_sharded_is_deterministic() {
        let run = || -> Vec<u64> {
            let mut b = PageBuffer::with_policy(8 * 4096, 4096, 1.0, EvictPolicy::Random);
            b.set_shards(4);
            let mut out = Vec::new();
            for p in 0..64u64 {
                if b.access(k(p % 24), false).is_none() {
                    while b.is_full() {
                        let ev = b.evict_victim().unwrap();
                        out.push(ev.key.page);
                        b.recycle(ev.data);
                    }
                    b.insert_with(k(p % 24), false, |_| {});
                }
            }
            out
        };
        assert_eq!(run(), run(), "round-robin shard fallback must reproduce");
    }

    // ---- atomic frame state through the shell ---------------------------

    #[test]
    fn pinned_page_is_never_the_victim() {
        let mut b = buf(2);
        b.insert_with(k(0), false, |_| {});
        b.insert_with(k(1), false, |_| {});
        assert!(b.pin(k(0)));
        let ev = b.evict_victim().unwrap(); // FIFO victim would be k(0)
        assert_eq!(ev.key, k(1), "pin must divert eviction");
        assert!(b.unpin(k(0)));
        b.recycle(ev.data);
        let ev = b.evict_victim().unwrap();
        assert_eq!(ev.key, k(0), "unpin restores evictability");
        assert!(!b.pin(k(9)), "pin of a non-resident page is refused");
    }

    #[test]
    fn generation_advances_on_slot_reuse() {
        let mut b = buf(1);
        b.insert_with(k(0), false, |_| {});
        let g0 = b.generation(k(0)).unwrap();
        let ev = b.evict_lru().unwrap();
        b.recycle(ev.data);
        b.insert_with(k(1), false, |_| {});
        let g1 = b.generation(k(1)).unwrap();
        assert!(g1 > g0, "slot reuse must bump the residency generation");
        assert_eq!(b.generation(k(0)), None);
    }

    #[test]
    fn demote_overrides_protection() {
        let mut b = buf_lru(3);
        for p in 0..3 {
            b.insert_with(k(p), false, |_| {});
        }
        b.access(k(0), false); // MRU
        assert!(b.demote(k(0)));
        let ev = b.evict_victim().unwrap();
        assert_eq!(ev.key, k(0), "demotion must beat recency");
        assert!(!b.demote(k(99)), "demote of a non-resident page is refused");
    }

    // ---- span coalescing -----------------------------------------------

    #[test]
    fn coalesce_merges_contiguous_runs() {
        let keys = [k(0), k(1), k(2), k(7), k(8), k(20)];
        let spans = PageSpan::coalesce(&keys, true);
        assert_eq!(
            spans,
            vec![
                PageSpan { start: k(0), pages: 3 },
                PageSpan { start: k(7), pages: 2 },
                PageSpan::single(k(20)),
            ]
        );
        // Flattened span pages enumerate the keys exactly.
        let flat: Vec<PageKey> = spans
            .iter()
            .flat_map(|s| (0..s.pages).map(|i| s.key_at(i)))
            .collect();
        assert_eq!(flat, keys);
    }

    #[test]
    fn coalesce_respects_region_boundaries() {
        let keys = [PageKey::new(1, 5), PageKey::new(2, 6)];
        let spans = PageSpan::coalesce(&keys, true);
        assert_eq!(spans.len(), 2, "different regions never merge");
    }

    #[test]
    fn coalesce_disabled_yields_singletons() {
        let keys = [k(0), k(1), k(2)];
        let spans = PageSpan::coalesce(&keys, false);
        assert_eq!(spans.len(), 3);
        assert!(spans.iter().all(|s| s.pages == 1));
    }

    #[test]
    fn span_geometry() {
        let s = PageSpan { start: PageKey::new(3, 10), pages: 4 };
        assert_eq!(s.key_at(3), PageKey::new(3, 13));
        assert_eq!(s.bytes(4096), 16384);
        assert_eq!(s.byte_offset(4096), 40960);
    }

    #[test]
    fn random_policy_always_finds_a_victim_when_full() {
        let mut b = PageBuffer::with_policy(4 * 4096, 4096, 1.0, EvictPolicy::Random);
        for p in 0..4 {
            b.insert_with(k(p), false, |_| {});
        }
        // Repeated evict/insert cycles must never fail (shell fallback
        // covers unlucky probe runs).
        for p in 4..40 {
            let ev = b.evict_victim().expect("a victim always exists");
            b.recycle(ev.data);
            b.insert_with(k(p), false, |_| {});
        }
        assert_eq!(b.resident_pages(), 4);
    }
}
