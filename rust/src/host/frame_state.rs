//! Packed atomic per-frame state word — the lock-free hit-path core of the
//! sharded page buffer.
//!
//! Every frame in [`PageBuffer`](crate::host::buffer::PageBuffer) carries
//! one `AtomicU64` packing the three pieces of state a concurrent hit path
//! needs without taking the shard lock (the aistore buffer-pool pattern:
//! one atomic word per frame, CAS transitions, generation-checked
//! writeback):
//!
//! ```text
//!  63                    16 15                1 0
//! ┌────────────────────────┬──────────────────┬──┐
//! │ residency generation   │ pin count        │D │
//! │ (48 bits)              │ (15 bits)        │  │
//! └────────────────────────┴──────────────────┴──┘
//! ```
//!
//! * **Dirty bit** (bit 0) — set by a write hit (`fetch_or`, no CAS loop),
//!   cleared only by a *generation-checked* CAS when a writeback completes,
//!   so a writeback racing a fresh write never silently drops the new
//!   dirtiness and a writeback for an *evicted-and-reused* frame (stale
//!   generation) never touches the new occupant.
//! * **Pin count** (bits 1–15) — readers/fills in flight. A pinned frame is
//!   not evictable; [`pin`](FrameState::pin) fails at [`MAX_PINS`] instead
//!   of wrapping into the generation field, [`unpin`](FrameState::unpin)
//!   panics on underflow (a pin-accounting bug, never a data race).
//! * **Residency generation** (bits 16–63) — bumped every time the frame is
//!   (re)occupied by a page. This is the ABA guard: an in-flight writeback
//!   snapshots the generation at eviction time and its completion CAS only
//!   lands if the frame still belongs to that occupancy. 48 bits wrap after
//!   2⁴⁸ reinsertion events per frame — unreachable in any run, and the
//!   wrap itself is harmless (only equality is ever tested, and no
//!   writeback survives 2⁴⁸ intervening reuses).
//!
//! All operations use `SeqCst`; the hot path is one atomic op per
//! pin/unpin/dirty transition and plain loads for the accessors, so hits
//! never enter a shard's slow path.

use std::sync::atomic::{AtomicU64, Ordering};

const DIRTY_BIT: u64 = 1;
const PIN_SHIFT: u32 = 1;
const PIN_BITS: u32 = 15;
const PIN_ONE: u64 = 1 << PIN_SHIFT;
const PIN_MASK: u64 = ((1 << PIN_BITS) - 1) << PIN_SHIFT;
const GEN_SHIFT: u32 = 16;
const GEN_MASK: u64 = !((1 << GEN_SHIFT) - 1);

/// Largest representable pin count (15 bits).
pub const MAX_PINS: u16 = (1 << PIN_BITS) - 1;

/// Error returned when a pin would overflow the 15-bit pin field.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PinOverflow;

/// One frame's packed atomic state word. See the module docs for layout.
#[derive(Debug, Default)]
pub struct FrameState(AtomicU64);

fn pins_of(word: u64) -> u16 {
    ((word & PIN_MASK) >> PIN_SHIFT) as u16
}

fn gen_of(word: u64) -> u64 {
    word >> GEN_SHIFT
}

impl FrameState {
    /// Fresh state for a newly occupied frame: generation 1 (0 means
    /// "never occupied"), zero pins, the given dirty bit.
    pub fn new(dirty: bool) -> Self {
        FrameState(AtomicU64::new((1 << GEN_SHIFT) | u64::from(dirty)))
    }

    /// The frame's current residency generation.
    pub fn generation(&self) -> u64 {
        gen_of(self.0.load(Ordering::SeqCst))
    }

    /// Current pin count.
    pub fn pins(&self) -> u16 {
        pins_of(self.0.load(Ordering::SeqCst))
    }

    /// Current dirty bit.
    pub fn is_dirty(&self) -> bool {
        self.0.load(Ordering::SeqCst) & DIRTY_BIT != 0
    }

    /// True if the frame may be chosen as an eviction victim (no pins).
    pub fn is_evictable(&self) -> bool {
        pins_of(self.0.load(Ordering::SeqCst)) == 0
    }

    /// Acquire a pin. Fails (leaving the word untouched) if the pin field
    /// is saturated — the caller backs off instead of corrupting the
    /// generation. Returns the new pin count.
    pub fn pin(&self) -> Result<u16, PinOverflow> {
        let mut cur = self.0.load(Ordering::SeqCst);
        loop {
            if pins_of(cur) == MAX_PINS {
                return Err(PinOverflow);
            }
            match self.0.compare_exchange_weak(
                cur,
                cur + PIN_ONE,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return Ok(pins_of(cur) + 1),
                Err(seen) => cur = seen,
            }
        }
    }

    /// Release a pin, returning the remaining count. Panics on underflow:
    /// an unpaired unpin is an accounting bug in the caller, not a state
    /// the word can represent.
    pub fn unpin(&self) -> u16 {
        let mut cur = self.0.load(Ordering::SeqCst);
        loop {
            assert!(pins_of(cur) > 0, "unpin of an unpinned frame");
            match self.0.compare_exchange_weak(
                cur,
                cur - PIN_ONE,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return pins_of(cur) - 1,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Mark the frame dirty (write hit). Single `fetch_or`, never lost to
    /// a racing writeback completion (the writeback's CAS will fail and
    /// retry against the newly dirty word — and then refuse, see
    /// [`clear_dirty_if_generation`](Self::clear_dirty_if_generation)).
    pub fn set_dirty(&self) {
        self.0.fetch_or(DIRTY_BIT, Ordering::SeqCst);
    }

    /// Writeback-completion handshake: clear the dirty bit *only* if the
    /// frame still holds residency generation `generation` (else the frame
    /// was evicted and reused — the classic ABA — and the stale writeback
    /// must not touch the new occupant's state). Returns `true` when the
    /// bit is clear for that generation on exit (cleared now, or already
    /// clean); `false` when the generation no longer matches.
    pub fn clear_dirty_if_generation(&self, generation: u64) -> bool {
        let mut cur = self.0.load(Ordering::SeqCst);
        loop {
            if gen_of(cur) != generation {
                return false;
            }
            if cur & DIRTY_BIT == 0 {
                return true;
            }
            match self.0.compare_exchange_weak(
                cur,
                cur & !DIRTY_BIT,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }

    /// The frame was reoccupied by a new page: bump the generation, install
    /// the new dirty bit, keep pins (which must be zero — eviction only
    /// picks unpinned victims). Returns the new generation.
    pub fn reinsert(&self, dirty: bool) -> u64 {
        let mut cur = self.0.load(Ordering::SeqCst);
        loop {
            assert!(pins_of(cur) == 0, "reinsert of a pinned frame");
            let next_gen = gen_of(cur).wrapping_add(1) & (GEN_MASK >> GEN_SHIFT);
            let next = (next_gen << GEN_SHIFT) | u64::from(dirty);
            match self
                .0
                .compare_exchange_weak(cur, next, Ordering::SeqCst, Ordering::SeqCst)
            {
                Ok(_) => return next_gen,
                Err(seen) => cur = seen,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_state_layout() {
        let clean = FrameState::new(false);
        assert_eq!(clean.generation(), 1);
        assert_eq!(clean.pins(), 0);
        assert!(!clean.is_dirty());
        assert!(clean.is_evictable());
        let dirty = FrameState::new(true);
        assert!(dirty.is_dirty());
        assert_eq!(dirty.generation(), 1);
    }

    #[test]
    fn pin_unpin_counts_and_evictability() {
        let s = FrameState::new(false);
        assert_eq!(s.pin(), Ok(1));
        assert_eq!(s.pin(), Ok(2));
        assert!(!s.is_evictable());
        assert_eq!(s.unpin(), 1);
        assert_eq!(s.unpin(), 0);
        assert!(s.is_evictable());
    }

    #[test]
    fn pin_overflow_is_refused_not_wrapped() {
        let s = FrameState::new(true);
        for _ in 0..MAX_PINS {
            s.pin().unwrap();
        }
        assert_eq!(s.pins(), MAX_PINS);
        // The saturated pin must fail cleanly without bleeding into the
        // generation field or the dirty bit.
        assert_eq!(s.pin(), Err(PinOverflow));
        assert_eq!(s.pins(), MAX_PINS);
        assert_eq!(s.generation(), 1);
        assert!(s.is_dirty());
    }

    #[test]
    #[should_panic(expected = "unpin of an unpinned frame")]
    fn unpin_underflow_panics() {
        FrameState::new(false).unpin();
    }

    #[test]
    fn dirty_bit_does_not_disturb_pins_or_generation() {
        let s = FrameState::new(false);
        s.pin().unwrap();
        s.set_dirty();
        assert!(s.is_dirty());
        assert_eq!(s.pins(), 1);
        assert_eq!(s.generation(), 1);
    }

    #[test]
    fn writeback_clear_requires_matching_generation() {
        let s = FrameState::new(true);
        let snap = s.generation();
        assert!(s.clear_dirty_if_generation(snap));
        assert!(!s.is_dirty());
        // Already-clean completion for the same generation is consistent.
        assert!(s.clear_dirty_if_generation(snap));
    }

    #[test]
    fn stale_generation_writeback_is_refused() {
        // The ABA scenario: writeback snapshots gen, the frame is evicted
        // and reused (gen bumps, new occupant is dirty), then the old
        // writeback completes. It must NOT clear the new occupant's bit.
        let s = FrameState::new(true);
        let old = s.generation();
        s.reinsert(true);
        assert!(!s.clear_dirty_if_generation(old));
        assert!(s.is_dirty(), "stale writeback cleared the new occupant");
        assert!(s.clear_dirty_if_generation(s.generation()));
    }

    #[test]
    fn dirty_after_writeback_snapshot_survives_the_clear_refusal_path() {
        // Same-generation race: writeback starts, a write hit re-dirties
        // the frame before completion. The completion clears the bit —
        // which is correct only because the shell re-checks dirtiness at
        // the *next* eviction; what must never happen is a clear under a
        // different generation. Pin the exact semantics here.
        let s = FrameState::new(true);
        let snap = s.generation();
        s.set_dirty(); // racing write, same occupancy
        assert!(s.clear_dirty_if_generation(snap));
        assert!(!s.is_dirty());
    }

    #[test]
    fn reinsert_bumps_generation_and_resets_dirty() {
        let s = FrameState::new(true);
        assert_eq!(s.reinsert(false), 2);
        assert!(!s.is_dirty());
        assert_eq!(s.reinsert(true), 3);
        assert!(s.is_dirty());
        assert_eq!(s.pins(), 0);
    }

    #[test]
    #[should_panic(expected = "reinsert of a pinned frame")]
    fn reinsert_of_pinned_frame_panics() {
        let s = FrameState::new(false);
        s.pin().unwrap();
        s.reinsert(false);
    }

    #[test]
    fn generation_wraps_inside_its_48_bit_field() {
        let s = FrameState::new(false);
        // Force the word to the top of the generation range.
        s.0.store(((1u64 << 48) - 1) << GEN_SHIFT, Ordering::SeqCst);
        assert_eq!(s.generation(), (1 << 48) - 1);
        assert_eq!(s.reinsert(true), 0, "wrap stays inside the field");
        assert!(s.is_dirty());
        assert_eq!(s.pins(), 0, "wrap never bleeds into the pin field");
    }

    #[test]
    fn many_pins_never_touch_neighbor_fields() {
        let s = FrameState::new(false);
        for i in 1..=100u16 {
            assert_eq!(s.pin(), Ok(i));
        }
        s.set_dirty();
        assert_eq!(s.generation(), 1);
        for i in (0..100u16).rev() {
            assert_eq!(s.unpin(), i);
        }
        assert!(s.is_dirty());
    }
}
