//! FAM-backed memory objects (§III, §IV-D).
//!
//! SODA interfaces with applications *only through memory objects*: a
//! FAM-backed object is a contiguous region in the process's virtual
//! address space whose backing pages live on a memory node. The C API is
//!
//! ```c
//! void *anon_obj = SODA_alloc(&num_bytes, NULL);        // anonymous
//! void *file_obj = SODA_alloc(&num_bytes, file_name);   // server-side file
//! ```
//!
//! Here an object is a [`FamHandle`] whose `region` is the memory-node
//! region id; "virtual addresses" are `(region, byte offset)` pairs. The
//! host agent maintains the metadata and the mapping between FAM-backed
//! objects and memory nodes, including the extended static-cache flag used
//! to route requests (§III-A).

use crate::memnode::RegionId;
use std::collections::HashMap;

/// Placement/caching hint for a FAM object.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Normal FAM-backed object; dynamic caching (if enabled on the DPU)
    /// applies.
    Default,
    /// Application requests this object be pinned in the DPU's static cache
    /// once populated (small, high access density — e.g. vertex data).
    Static,
}

/// A mapped FAM-backed memory object.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FamHandle {
    pub region: RegionId,
    pub bytes: u64,
    pub placement: Placement,
    /// Writable mappings are restricted to a single client (§III: coherence
    /// is avoided, not solved — snoop/directory protocols are out of scope).
    pub writable: bool,
}

impl FamHandle {
    pub fn pages(&self, chunk_bytes: u64) -> u64 {
        self.bytes.div_ceil(chunk_bytes)
    }
}

/// Per-process object table: named objects → handles.
#[derive(Clone, Debug, Default)]
pub struct ObjectTable {
    objects: HashMap<String, FamHandle>,
}

impl ObjectTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, h: FamHandle) -> Option<FamHandle> {
        self.objects.insert(name.into(), h)
    }

    pub fn get(&self, name: &str) -> Option<FamHandle> {
        self.objects.get(name).copied()
    }

    pub fn remove(&mut self, name: &str) -> Option<FamHandle> {
        self.objects.remove(name)
    }

    pub fn len(&self) -> usize {
        self.objects.len()
    }

    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }

    pub fn total_bytes(&self) -> u64 {
        self.objects.values().map(|h| h.bytes).sum()
    }

    pub fn handles(&self) -> impl Iterator<Item = (&str, FamHandle)> {
        self.objects.iter().map(|(k, v)| (k.as_str(), *v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_page_count_rounds_up() {
        let h = FamHandle {
            region: 1,
            bytes: 100_000,
            placement: Placement::Default,
            writable: true,
        };
        assert_eq!(h.pages(65536), 2);
        assert_eq!(h.pages(4096), 25);
    }

    #[test]
    fn table_insert_get_remove() {
        let mut t = ObjectTable::new();
        let h = FamHandle {
            region: 7,
            bytes: 4096,
            placement: Placement::Static,
            writable: false,
        };
        assert!(t.insert("vertices", h).is_none());
        assert_eq!(t.get("vertices"), Some(h));
        assert_eq!(t.len(), 1);
        assert_eq!(t.total_bytes(), 4096);
        assert_eq!(t.remove("vertices"), Some(h));
        assert!(t.is_empty());
    }

    #[test]
    fn reinsert_returns_previous() {
        let mut t = ObjectTable::new();
        let a = FamHandle { region: 1, bytes: 10, placement: Placement::Default, writable: true };
        let b = FamHandle { region: 2, bytes: 20, placement: Placement::Default, writable: true };
        t.insert("x", a);
        assert_eq!(t.insert("x", b), Some(a));
        assert_eq!(t.get("x"), Some(b));
    }
}
