//! Host agent — SODA's compute-node component (§III).

pub mod agent;
pub mod buffer;
pub mod fam;

pub use agent::{HostAgent, HostStats, HostTiming};
pub use buffer::{BufferStats, EvictPolicy, EvictedPage, PageBuffer, PageKey, PageSpan};
pub use fam::{FamHandle, ObjectTable, Placement};
