//! Host agent — SODA's compute-node component (§III).
//!
//! ## Shard / worker architecture
//!
//! The compute side scales along two orthogonal axes, both defaulting to 1
//! (where every path is bit-identical to the original single-threaded
//! shell):
//!
//! * **P buffer shards** ([`PageBuffer::set_shards`]): the residency table
//!   splits into P shards keyed by a `PageKey` hash over aligned 16-page
//!   runs (coalesced fault spans stay shard-local). Each shard owns its map
//!   slice, its own [`ReplacementPolicy`](crate::cache::ReplacementPolicy)
//!   engine and RNG; cross-shard eviction order is reconstructed exactly
//!   for the deterministic policies by merging per-shard `peek_victim`
//!   candidates on a per-frame stamp. The hit path never takes a shard's
//!   slow path: dirty bit, pin count and residency generation live in one
//!   packed `AtomicU64` per frame ([`frame_state::FrameState`] — bit 0
//!   dirty, bits 1–15 pin count, bits 16–63 generation), so
//!   pin/unpin/mark-dirty are single atomic ops and writeback completions
//!   are generation-checked CASes (the ABA guard for reused frames).
//! * **W host workers** ([`HostAgent::set_host_workers`]): a superstep's
//!   fault windows partition their coalesced miss spans across W worker
//!   lanes by shard (per-shard miss queues; duplicate misses of one page
//!   coalesce onto the shard leader's in-flight fetch). Each lane posts on
//!   its own QP lane, so a window's doorbell cost is the *max* over lanes
//!   instead of the serial sum, and eviction management + writeback time
//!   retires on background lane clocks instead of the fault critical path.
//!   Virtual-time merging is deterministic — outputs, fault counts and
//!   data-plane bytes are identical at any W, and `RunMetrics` stays
//!   reproducible.

pub mod agent;
pub mod buffer;
pub mod fam;
pub mod frame_state;

pub use agent::{HostAgent, HostStats, HostTiming, PushdownMode};
pub use buffer::{BufferStats, EvictPolicy, EvictedPage, PageBuffer, PageKey, PageSpan};
pub use fam::{FamHandle, ObjectTable, Placement};
pub use frame_state::{FrameState, PinOverflow, MAX_PINS};
