//! The host agent (§III) — SODA's compute-node runtime.
//!
//! Manages the staging buffer for FAM data, monitors accesses to FAM-backed
//! objects (the `userfaultfd` mechanism of §IV-D, realized here as an
//! explicit `touch` API with identical interception points), issues
//! requests on miss, and evicts dirty chunks when the buffer fills. The
//! communication buffer is bound to the NUMA node closest to the NIC when
//! NUMA-aware placement is enabled (§III) — the measured difference is the
//! whole of Fig 3.

use super::buffer::{BufferStats, PageBuffer, PageKey};
use super::fam::{FamHandle, ObjectTable, Placement};
use crate::backend::{FetchSource, RemoteStore};
use crate::fabric::qp::QpPool;
use crate::memnode::RegionId;
use crate::sim::Ns;
use crate::util::fxhash::FxHashMap;

/// Host-side CPU cost model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HostTiming {
    /// uffd trap + handler dispatch + metadata lookup on a miss.
    pub fault_trap_ns: Ns,
    /// Cost of touching a resident page. Near zero: with uffd management a
    /// hit is an ordinary mapped-memory access served by the MMU — the
    /// runtime never sees it (the same reason eviction is fault-ordered).
    pub hit_ns: Ns,
    /// Buffer management per eviction.
    pub evict_mgmt_ns: Ns,
    /// Zero-fill of a first-touch anonymous page (no remote fetch needed).
    pub zero_fill_ns: Ns,
}

impl Default for HostTiming {
    fn default() -> Self {
        HostTiming {
            fault_trap_ns: 2_500,
            hit_ns: 0,
            evict_mgmt_ns: 300,
            zero_fill_ns: 1_500,
        }
    }
}

/// Host agent statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct HostStats {
    pub faults: u64,
    pub zero_fills: u64,
    pub writebacks: u64,
    /// Total fault stall time across threads (miss latency sum).
    pub stall_ns: Ns,
    /// Fetches by source, indexed by [`FetchSource::index`].
    pub sources: [u64; FetchSource::COUNT],
}

impl HostStats {
    fn count(&mut self, src: FetchSource) {
        self.sources[src.index()] += 1;
    }

    pub fn fetched(&self, src: FetchSource) -> u64 {
        self.sources[src.index()]
    }
}

/// A compute-node process's SODA runtime endpoint.
pub struct HostAgent {
    pub name: String,
    buffer: PageBuffer,
    store: Box<dyn RemoteStore>,
    objects: ObjectTable,
    qp: QpPool,
    /// NUMA node holding the communication buffer.
    pub numa_node: usize,
    threads: usize,
    timing: HostTiming,
    chunk_bytes: u64,
    /// Pages with meaningful remote content (anonymous first-touch pages
    /// are zero-filled locally, like a kernel's zero page).
    materialized: FxHashMap<RegionId, Vec<u64>>,
    stats: HostStats,
    /// Optional miss trace `(time, page)` for workload replay (Fig 8).
    trace: Option<Vec<(Ns, PageKey)>>,
}

impl HostAgent {
    /// `numa_aware` picks the NIC-local node (the libnuma binding of §IV-A);
    /// otherwise the "default behavior" lands the buffer on node 0.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: impl Into<String>,
        store: Box<dyn RemoteStore>,
        buffer_bytes: u64,
        chunk_bytes: u64,
        evict_threshold: f64,
        threads: usize,
        qp_count: usize,
        numa_node: usize,
        timing: HostTiming,
    ) -> Self {
        Self::with_policy(
            name,
            store,
            buffer_bytes,
            chunk_bytes,
            evict_threshold,
            threads,
            qp_count,
            numa_node,
            timing,
            super::buffer::EvictPolicy::FaultFifo,
            PageBuffer::DEFAULT_RNG_SEED,
        )
    }

    /// Like [`Self::new`] with an explicit buffer eviction policy (the
    /// policy ablation of `abl-evict`) and the RNG seed stochastic
    /// policies draw from (the service passes `ClusterConfig::seed`).
    #[allow(clippy::too_many_arguments)]
    pub fn with_policy(
        name: impl Into<String>,
        store: Box<dyn RemoteStore>,
        buffer_bytes: u64,
        chunk_bytes: u64,
        evict_threshold: f64,
        threads: usize,
        qp_count: usize,
        numa_node: usize,
        timing: HostTiming,
        policy: super::buffer::EvictPolicy,
        buffer_seed: u64,
    ) -> Self {
        HostAgent {
            name: name.into(),
            buffer: PageBuffer::with_policy_seeded(
                buffer_bytes,
                chunk_bytes,
                evict_threshold,
                policy,
                buffer_seed,
            ),
            store,
            objects: ObjectTable::new(),
            qp: QpPool::new(qp_count.max(1)),
            numa_node,
            threads: threads.max(1),
            timing,
            chunk_bytes,
            materialized: FxHashMap::default(),
            stats: HostStats::default(),
            trace: None,
        }
    }

    /// Start recording the miss (fault) trace.
    pub fn enable_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Take the recorded trace (stops recording).
    pub fn take_trace(&mut self) -> Vec<(Ns, PageKey)> {
        self.trace.take().unwrap_or_default()
    }

    pub fn chunk_bytes(&self) -> u64 {
        self.chunk_bytes
    }

    pub fn buffer_stats(&self) -> BufferStats {
        self.buffer.stats()
    }

    pub fn stats(&self) -> HostStats {
        self.stats
    }

    pub fn store_name(&self) -> &'static str {
        self.store.name()
    }

    pub fn object(&self, name: &str) -> Option<FamHandle> {
        self.objects.get(name)
    }

    fn mark_materialized(&mut self, key: PageKey) {
        let bits = self.materialized.entry(key.region).or_default();
        let word = (key.page / 64) as usize;
        if bits.len() <= word {
            bits.resize(word + 1, 0);
        }
        bits[word] |= 1 << (key.page % 64);
    }

    fn is_materialized(&self, key: PageKey) -> bool {
        self.materialized
            .get(&key.region)
            .map(|bits| {
                let word = (key.page / 64) as usize;
                word < bits.len() && bits[word] & (1 << (key.page % 64)) != 0
            })
            .unwrap_or(false)
    }

    fn mark_region_materialized(&mut self, region: RegionId, pages: u64) {
        let words = (pages as usize).div_ceil(64);
        self.materialized.insert(region, vec![u64::MAX; words]);
    }

    /// `SODA_alloc`: create a FAM-backed object. `file` pre-loads server-side
    /// data (its pages are immediately materialized); anonymous objects
    /// zero-fill on first touch. Returns the handle and completion time.
    pub fn alloc(
        &mut self,
        now: Ns,
        name: impl Into<String>,
        bytes: u64,
        file: Option<Vec<u8>>,
        placement: Placement,
    ) -> (FamHandle, Ns) {
        let file_backed = file.is_some();
        let (region, done) = self.store.alloc(now, bytes, file);
        let handle = FamHandle {
            region,
            bytes,
            placement,
            writable: true,
        };
        if file_backed {
            self.mark_region_materialized(region, handle.pages(self.chunk_bytes));
        }
        self.objects.insert(name, handle);
        (handle, done)
    }

    /// Map an object another process allocated (read-only sharing; §III
    /// restricts writable mappings to single clients).
    pub fn map_shared(&mut self, name: impl Into<String>, mut handle: FamHandle) -> FamHandle {
        handle.writable = false;
        self.mark_region_materialized(handle.region, handle.pages(self.chunk_bytes));
        self.objects.insert(name, handle);
        handle
    }

    /// Free an object and its region.
    pub fn dealloc(&mut self, now: Ns, name: &str) -> Option<Ns> {
        let handle = self.objects.remove(name)?;
        self.materialized.remove(&handle.region);
        Some(self.store.free(now, handle.region))
    }

    /// The page-fault path: ensure `key` is resident, return completion.
    pub fn touch_page(&mut self, now: Ns, tid: usize, key: PageKey, write: bool) -> Ns {
        if self.buffer.access(key, write).is_some() {
            return now + self.timing.hit_ns;
        }
        self.stats.faults += 1;
        if let Some(trace) = &mut self.trace {
            trace.push((now, key));
        }
        let mut t = now + self.timing.fault_trap_ns;

        // Proactive eviction: keep the buffer under its threshold; dirty
        // chunks are written back (the store decides whether the host blocks
        // for durability or is released at DPU hand-off).
        while self.buffer.over_threshold() || self.buffer.is_full() {
            let Some(ev) = self.buffer.evict_lru() else { break };
            t += self.timing.evict_mgmt_ns;
            if ev.dirty {
                let released = self.store.writeback(t, ev.key, &ev.data);
                self.mark_materialized(ev.key);
                self.stats.writebacks += 1;
                t = released;
            }
            self.buffer.recycle(ev.data);
        }

        if self.is_materialized(key) {
            // Post the request on this thread's QP and fetch.
            t += self.qp.post_cost_ns(tid, self.threads, 1);
            let frame = self.buffer.insert_with(key, write, |_| {});
            let (done, src) = self.store.fetch(t, key, self.numa_node, frame);
            self.stats.count(src);
            self.stats.stall_ns += done.saturating_sub(now);
            done
        } else {
            // Anonymous first touch: local zero-fill, no remote traffic.
            self.buffer.insert_with(key, write, |d| d.fill(0));
            self.stats.zero_fills += 1;
            let done = t + self.timing.zero_fill_ns;
            self.stats.stall_ns += done.saturating_sub(now);
            done
        }
    }

    /// Read `out.len()` bytes at `offset` of a region, faulting as needed.
    pub fn read_bytes(
        &mut self,
        now: Ns,
        tid: usize,
        region: RegionId,
        offset: u64,
        out: &mut [u8],
    ) -> Ns {
        let mut t = now;
        let mut done = 0usize;
        while done < out.len() {
            let abs = offset + done as u64;
            let page = abs / self.chunk_bytes;
            let in_page = (abs % self.chunk_bytes) as usize;
            let take = ((self.chunk_bytes as usize - in_page).min(out.len() - done)).max(1);
            let key = PageKey::new(region, page);
            t = self.touch_page(t, tid, key, false);
            let frame = self.buffer.peek(key).expect("just touched");
            out[done..done + take].copy_from_slice(&frame[in_page..in_page + take]);
            done += take;
        }
        t
    }

    /// Write bytes at `offset`, faulting pages (read-modify-write) and
    /// marking them dirty.
    pub fn write_bytes(
        &mut self,
        now: Ns,
        tid: usize,
        region: RegionId,
        offset: u64,
        data: &[u8],
    ) -> Ns {
        let mut t = now;
        let mut done = 0usize;
        while done < data.len() {
            let abs = offset + done as u64;
            let page = abs / self.chunk_bytes;
            let in_page = (abs % self.chunk_bytes) as usize;
            let take = ((self.chunk_bytes as usize - in_page).min(data.len() - done)).max(1);
            let key = PageKey::new(region, page);
            t = self.touch_page(t, tid, key, true);
            let frame = self.buffer.peek(key).expect("just touched");
            frame[in_page..in_page + take].copy_from_slice(&data[done..done + take]);
            done += take;
        }
        t
    }

    /// Flush all dirty pages to the store (barrier / pre-pin sync).
    pub fn flush(&mut self, now: Ns) -> Ns {
        let mut t = now;
        for ev in self.buffer.drain_dirty() {
            let released = self.store.writeback(t, ev.key, &ev.data);
            self.mark_materialized(ev.key);
            self.stats.writebacks += 1;
            t = released;
            self.buffer.recycle(ev.data);
        }
        t
    }

    /// Pin an object into the DPU static cache (flushes first so the bulk
    /// load sees current data). No-op `None` on DPU-less backends.
    pub fn pin_static(&mut self, now: Ns, name: &str) -> Option<Ns> {
        let handle = self.objects.get(name)?;
        let t = self.flush(now);
        self.store.pin_static(t, handle.region)
    }

    /// Drop every resident page (cold-cache boundary between experiment
    /// phases; dirty pages are flushed first).
    pub fn invalidate_buffer(&mut self, now: Ns) -> Ns {
        let t = self.flush(now);
        while let Some(ev) = self.buffer.evict_lru() {
            debug_assert!(!ev.dirty);
            self.buffer.recycle(ev.data);
        }
        t
    }
}

impl std::fmt::Debug for HostAgent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HostAgent")
            .field("name", &self.name)
            .field("store", &self.store.name())
            .field("resident_pages", &self.buffer.resident_pages())
            .field("stats", &self.stats)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::MemServerStore;
    use crate::coordinator::cluster::Cluster;
    use crate::coordinator::config::ClusterConfig;

    fn agent_with_buffer_pages(pages: u64) -> (HostAgent, Cluster) {
        let cluster = Cluster::build(ClusterConfig::tiny());
        let chunk = cluster.config().chunk_bytes;
        let store = Box::new(MemServerStore::new(cluster.clone()));
        let agent = HostAgent::new(
            "p0",
            store,
            pages * chunk,
            chunk,
            1.0,
            4,
            4,
            2,
            HostTiming::default(),
        );
        (agent, cluster)
    }

    #[test]
    fn anonymous_first_touch_is_local_zero_fill() {
        let (mut a, cluster) = agent_with_buffer_pages(8);
        let (h, t0) = a.alloc(0, "x", 4 * a.chunk_bytes(), None, Placement::Default);
        cluster.reset_stats();
        let mut out = vec![0xFFu8; 16];
        a.read_bytes(t0, 0, h.region, 0, &mut out);
        assert!(out.iter().all(|&b| b == 0), "anon pages read as zero");
        assert_eq!(cluster.network_stats().on_demand_bytes(), 0, "no remote fetch");
        assert_eq!(a.stats().zero_fills, 1);
    }

    #[test]
    fn write_then_evict_then_read_roundtrips_through_memnode() {
        let (mut a, cluster) = agent_with_buffer_pages(2);
        let chunk = a.chunk_bytes();
        let (h, t0) = a.alloc(0, "x", 8 * chunk, None, Placement::Default);
        // Write distinct bytes to 4 pages; buffer holds only 2 → evictions.
        let mut t = t0;
        for p in 0..4u64 {
            let data = vec![p as u8 + 1; chunk as usize];
            t = a.write_bytes(t, 0, h.region, p * chunk, &data);
        }
        assert!(a.stats().writebacks >= 2, "dirty evictions happened");
        // Read back page 0 (evicted long ago) — must refetch real bytes.
        let mut out = vec![0u8; chunk as usize];
        a.read_bytes(t, 0, h.region, 0, &mut out);
        assert!(out.iter().all(|&b| b == 1), "page 0 data survived eviction");
        assert!(cluster.network_stats().writeback_bytes() > 0);
    }

    #[test]
    fn buffer_hits_avoid_remote_traffic() {
        let (mut a, cluster) = agent_with_buffer_pages(8);
        let chunk = a.chunk_bytes();
        let file = vec![7u8; (2 * chunk) as usize];
        let (h, t0) = a.alloc(0, "f", 2 * chunk, Some(file), Placement::Default);
        let mut out = vec![0u8; 64];
        let t1 = a.read_bytes(t0, 0, h.region, 0, &mut out);
        let before = cluster.network_stats().on_demand_bytes();
        let t2 = a.read_bytes(t1, 0, h.region, 8, &mut out);
        assert_eq!(cluster.network_stats().on_demand_bytes(), before, "hit: no traffic");
        assert!(t2 - t1 < 1_000, "hit latency is sub-µs");
    }

    #[test]
    fn read_spanning_pages() {
        let (mut a, _cluster) = agent_with_buffer_pages(8);
        let chunk = a.chunk_bytes();
        let mut file = vec![0u8; (2 * chunk) as usize];
        file[chunk as usize - 1] = 1;
        file[chunk as usize] = 2;
        let (h, t0) = a.alloc(0, "f", 2 * chunk, Some(file), Placement::Default);
        let mut out = [0u8; 2];
        a.read_bytes(t0, 0, h.region, chunk - 1, &mut out);
        assert_eq!(out, [1, 2]);
        assert_eq!(a.stats().faults, 2, "two pages faulted");
    }

    #[test]
    fn flush_makes_data_durable_without_eviction() {
        let (mut a, _c) = agent_with_buffer_pages(8);
        let chunk = a.chunk_bytes();
        let (h, t0) = a.alloc(0, "x", 2 * chunk, None, Placement::Default);
        let data = vec![9u8; chunk as usize];
        let t1 = a.write_bytes(t0, 0, h.region, 0, &data);
        let t2 = a.flush(t1);
        assert!(t2 > t1);
        assert_eq!(a.stats().writebacks, 1);
        // Invalidate and re-read: the data must come back from the store.
        let t3 = a.invalidate_buffer(t2);
        let mut out = vec![0u8; chunk as usize];
        a.read_bytes(t3, 0, h.region, 0, &mut out);
        assert!(out.iter().all(|&b| b == 9));
    }

    #[test]
    fn dealloc_frees_the_region() {
        let (mut a, cluster) = agent_with_buffer_pages(4);
        let (_, t0) = a.alloc(0, "x", 4096, None, Placement::Default);
        let used_before = cluster.with(|i| i.memnode.store.used());
        assert!(used_before > 0);
        a.dealloc(t0, "x").expect("object exists");
        assert_eq!(cluster.with(|i| i.memnode.store.used()), 0);
        assert!(a.object("x").is_none());
    }

    #[test]
    fn stall_accounting_accumulates() {
        let (mut a, _c) = agent_with_buffer_pages(4);
        let chunk = a.chunk_bytes();
        let (h, t0) = a.alloc(0, "f", chunk, Some(vec![1; chunk as usize]), Placement::Default);
        let mut out = vec![0u8; 8];
        a.read_bytes(t0, 0, h.region, 0, &mut out);
        assert!(a.stats().stall_ns > 0);
        assert_eq!(a.stats().fetched(FetchSource::MemNode), 1);
    }
}
