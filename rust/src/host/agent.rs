//! The host agent (§III) — SODA's compute-node runtime.
//!
//! Manages the staging buffer for FAM data, monitors accesses to FAM-backed
//! objects (the `userfaultfd` mechanism of §IV-D, realized here as an
//! explicit `touch` API with identical interception points), issues
//! requests on miss, and evicts dirty chunks when the buffer fills. The
//! communication buffer is bound to the NUMA node closest to the NIC when
//! NUMA-aware placement is enabled (§III) — the measured difference is the
//! whole of Fig 3.
//!
//! ## Batched fault engine
//!
//! [`HostAgent::touch_pages`] (and the span-based [`HostAgent::read_bytes`]
//! / [`HostAgent::write_bytes`] built on it) is the batched counterpart of
//! the per-page fault path: a span's pages are partitioned into hits /
//! zero-fills / misses with one batched residency pre-scan, contiguous
//! misses are coalesced into multi-page [`PageSpan`] range requests, the
//! whole miss set is posted with a *single doorbell*
//! ([`QueuePair::post_batch`](crate::fabric::qp::QueuePair::post_batch)),
//! and the backend overlaps the fetches' network round trips
//! ([`crate::backend::RemoteStore::fetch_batch`]) — so a k-page miss burst
//! costs ~max(per-stage service) + one round trip instead of k round trips.
//! Buffer metadata operations (hit touches, evictions, inserts) replay in
//! exactly the per-page order, so final buffer state, fault counts and
//! bytes-on-wire are identical to the sequential loop; only completion
//! times improve. `SodaConfig::max_batch_pages` bounds the window (1
//! disables batching) and `SodaConfig::coalesce_fetch` toggles range
//! coalescing — the knobs the extended Fig 11 breakdown and `abl-batch`
//! sweep.
//!
//! ## Multi-worker fault service
//!
//! [`HostAgent::set_host_workers`] turns the serial fault handler into W
//! concurrent worker lanes (`SodaConfig::host_workers`, the `abl-scaling`
//! axis). A window's coalesced miss spans partition across lanes by the
//! page buffer's shard hash, each lane posts its sub-batch on its own QP
//! (the pool grows to `qp_count * W`, keeping the shared-contention
//! condition invariant), and the window's post cost becomes the max over
//! lanes instead of the serial sum. Eviction management and dirty
//! writebacks retire on background lane clocks rather than the fault
//! critical path; the [`HostAgent::flush`] barrier joins those lanes.
//! Every store call still executes in the serial program order, so
//! outputs, fault counts, final buffer state and bytes-on-wire are
//! identical at any W — only (deterministic, virtual) completion times
//! change, and `W == 1` is the seed's serial agent bit for bit.

use super::buffer::{shard_index, BufferStats, PageBuffer, PageKey, PageSpan};
use super::fam::{FamHandle, ObjectTable, Placement};
use crate::backend::{FetchSource, RemoteStore};
use crate::fabric::qp::QpPool;
use crate::memnode::{MemError, RegionId};
use crate::sim::Ns;
use crate::util::fxhash::FxHashMap;

/// Per-shard miss queues of one batched fault window.
///
/// Misses are recorded in global discovery order (the order the coalesced
/// span list must preserve). Each distinct page gets one *leader* entry;
/// a later touch of the same page inside the window does not issue a
/// second fetch — it joins the leader's waiter list and is served by the
/// leader's in-flight fetch at replay time. With W workers the leaders
/// partition across worker lanes by the buffer's shard hash (see
/// [`shard_index`]), so each lane posts only its own sub-batch.
#[derive(Debug, Default)]
struct MissQueues {
    /// Distinct misses in discovery order — the span-list source.
    leaders: Vec<PageKey>,
    /// Waiters coalesced per leader (parallel to `leaders`).
    waiters: Vec<u32>,
    /// Fast-path flag: while the discovered keys stay ascending, dedup is
    /// an O(1) tail comparison (byte spans and the graph paths produce
    /// ascending keys); the linear scan only runs for out-of-order
    /// `touch_pages` callers.
    ascending: bool,
}

impl MissQueues {
    fn begin(&mut self) {
        self.leaders.clear();
        self.waiters.clear();
        self.ascending = true;
    }

    /// Record a discovered miss; returns `true` if this page became a
    /// leader (new in-flight fetch) and `false` if it coalesced onto an
    /// existing leader as a waiter.
    fn note_miss(&mut self, key: PageKey) -> bool {
        let dup = match self.leaders.last() {
            None => None,
            Some(&m) if m == key => Some(self.leaders.len() - 1),
            Some(&m) if self.ascending && key > m => None,
            _ => self.leaders.iter().position(|&m| m == key),
        };
        if let Some(leader) = dup {
            self.waiters[leader] += 1;
            return false;
        }
        if self.leaders.last().is_some_and(|&m| key < m) {
            self.ascending = false;
        }
        self.leaders.push(key);
        self.waiters.push(0);
        true
    }

    /// Waiters coalesced across the whole window.
    fn total_waiters(&self) -> u64 {
        self.waiters.iter().map(|&w| u64::from(w)).sum()
    }
}

/// Host-side CPU cost model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HostTiming {
    /// uffd trap + handler dispatch + metadata lookup on a miss.
    pub fault_trap_ns: Ns,
    /// Cost of touching a resident page. Near zero: with uffd management a
    /// hit is an ordinary mapped-memory access served by the MMU — the
    /// runtime never sees it (the same reason eviction is fault-ordered).
    pub hit_ns: Ns,
    /// Buffer management per eviction.
    pub evict_mgmt_ns: Ns,
    /// Zero-fill of a first-touch anonymous page (no remote fetch needed).
    pub zero_fill_ns: Ns,
}

impl Default for HostTiming {
    fn default() -> Self {
        HostTiming {
            fault_trap_ns: 2_500,
            hit_ns: 0,
            evict_mgmt_ns: 300,
            zero_fill_ns: 1_500,
        }
    }
}

/// Operator-pushdown routing policy of a host agent.
///
/// * `Off` — never build kernel descriptors; every superstep pages (the
///   seed behavior, and the default).
/// * `On` — always attempt pushdown when the operator is expressible; the
///   DPU may still decline, falling back to paging.
/// * `Auto` — attempt pushdown only when it is expected to pay: the spans
///   are mostly non-resident host-side and the descriptor + operand +
///   results are smaller than the paging path's page estimate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PushdownMode {
    #[default]
    Off,
    On,
    Auto,
}

impl PushdownMode {
    pub fn name(&self) -> &'static str {
        match self {
            PushdownMode::Off => "off",
            PushdownMode::On => "on",
            PushdownMode::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Option<PushdownMode> {
        match s {
            "off" => Some(PushdownMode::Off),
            "on" => Some(PushdownMode::On),
            "auto" => Some(PushdownMode::Auto),
            _ => None,
        }
    }
}

/// Host agent statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct HostStats {
    pub faults: u64,
    pub zero_fills: u64,
    pub writebacks: u64,
    /// Total fault stall time across threads (miss latency sum; a batched
    /// window stalls its thread once, not once per page).
    pub stall_ns: Ns,
    /// Fetches by source, indexed by [`FetchSource::index`].
    pub sources: [u64; FetchSource::COUNT],
    /// WQEs posted on the data-plane QPs (snapshot at [`HostAgent::stats`]).
    pub qp_posted: u64,
    /// Doorbells rung — `qp_posted / qp_doorbells` is the realized
    /// doorbell-batching factor the `abl-batch` ablation reports.
    pub qp_doorbells: u64,
    /// Frontier-hint messages posted over the host→DPU hint channel
    /// (only counted when the backend's prefetcher actually consumed one).
    pub hints_sent: u64,
    /// Dirty pages whose bounded writeback attempt failed and were parked
    /// for a later retry instead of being dropped (fault injection only).
    pub writeback_requeues: u64,
    /// Duplicated completions absorbed by the QPs' saturating counters
    /// (snapshot at [`HostAgent::stats`]; fault injection only).
    pub qp_over_completions: u64,
    /// Window misses that coalesced onto an already-in-flight fetch of the
    /// same page (the waiter lists of the per-shard miss queues) instead
    /// of issuing their own.
    pub miss_waiters: u64,
    /// Pushdown kernel descriptors executed by the backend's near-data
    /// compute (a superstep served at result granularity, not pages).
    pub pushdowns: u64,
    /// Pushdown attempts the backend declined — the superstep fell back to
    /// the paging path (always correct, just byte-heavier).
    pub pushdown_fallbacks: u64,
}

impl HostStats {
    fn count(&mut self, src: FetchSource) {
        self.sources[src.index()] += 1;
    }

    pub fn fetched(&self, src: FetchSource) -> u64 {
        self.sources[src.index()]
    }
}

/// A compute-node process's SODA runtime endpoint.
pub struct HostAgent {
    pub name: String,
    buffer: PageBuffer,
    store: Box<dyn RemoteStore>,
    objects: ObjectTable,
    qp: QpPool,
    /// NUMA node holding the communication buffer.
    pub numa_node: usize,
    threads: usize,
    timing: HostTiming,
    chunk_bytes: u64,
    /// Pages with meaningful remote content (anonymous first-touch pages
    /// are zero-filled locally, like a kernel's zero page).
    materialized: FxHashMap<RegionId, Vec<u64>>,
    stats: HostStats,
    /// Optional miss trace `(time, page)` for workload replay (Fig 8).
    trace: Option<Vec<(Ns, PageKey)>>,
    /// Max pages per batched fault window (1 = per-page sequential path).
    max_batch_pages: u64,
    /// Merge contiguous misses into multi-page range requests.
    coalesce_fetch: bool,
    /// Reused staging buffer for batched miss payloads (no steady-state
    /// allocation on the fault path).
    fetch_scratch: Vec<u8>,
    /// Reused key list for the span walks of `read_bytes`/`write_bytes`.
    span_keys: Vec<PageKey>,
    /// Reused per-window miss queues (leader/waiter coalescing).
    miss_queues: MissQueues,
    /// Reused per-window consumed-slot marks (parallel to the leaders).
    miss_used: Vec<bool>,
    /// Dirty pages whose bounded writeback failed: the *only* copy of the
    /// data until a retry lands. Consulted on every fault so a parked page
    /// is restored locally, never re-fetched stale from the store. Always
    /// empty when fault injection is off.
    pending_writebacks: Vec<(PageKey, Box<[u8]>)>,
    /// Concurrent host fault workers (W). 1 is the seed's serial agent,
    /// bit for bit. At W > 1 a window's miss spans partition across W
    /// worker lanes and eviction work retires on `lane_clocks` instead of
    /// the fault critical path.
    host_workers: usize,
    /// QPs per worker lane. The pool holds `base_qp_count * host_workers`
    /// queues so each lane posts on its own QP and the pool's
    /// shared-contention condition stays invariant in W.
    base_qp_count: usize,
    /// Per-lane "busy until" clocks for offloaded eviction work (absolute
    /// virtual time; only written at W > 1, joined by the `flush` barrier).
    lane_clocks: Vec<Ns>,
    /// Reused per-lane span counts of one window's post.
    lane_spans: Vec<u64>,
    /// Operator-pushdown routing policy ([`PushdownMode::Off`] keeps the
    /// seed's pure paging path bit for bit).
    pushdown: PushdownMode,
}

impl HostAgent {
    /// `numa_aware` picks the NIC-local node (the libnuma binding of §IV-A);
    /// otherwise the "default behavior" lands the buffer on node 0.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: impl Into<String>,
        store: Box<dyn RemoteStore>,
        buffer_bytes: u64,
        chunk_bytes: u64,
        evict_threshold: f64,
        threads: usize,
        qp_count: usize,
        numa_node: usize,
        timing: HostTiming,
    ) -> Self {
        Self::with_policy(
            name,
            store,
            buffer_bytes,
            chunk_bytes,
            evict_threshold,
            threads,
            qp_count,
            numa_node,
            timing,
            super::buffer::EvictPolicy::FaultFifo,
            PageBuffer::DEFAULT_RNG_SEED,
        )
    }

    /// Like [`Self::new`] with an explicit buffer eviction policy (the
    /// policy ablation of `abl-evict`) and the RNG seed stochastic
    /// policies draw from (the service passes `ClusterConfig::seed`).
    #[allow(clippy::too_many_arguments)]
    pub fn with_policy(
        name: impl Into<String>,
        store: Box<dyn RemoteStore>,
        buffer_bytes: u64,
        chunk_bytes: u64,
        evict_threshold: f64,
        threads: usize,
        qp_count: usize,
        numa_node: usize,
        timing: HostTiming,
        policy: super::buffer::EvictPolicy,
        buffer_seed: u64,
    ) -> Self {
        HostAgent {
            name: name.into(),
            buffer: PageBuffer::with_policy_seeded(
                buffer_bytes,
                chunk_bytes,
                evict_threshold,
                policy,
                buffer_seed,
            ),
            store,
            objects: ObjectTable::new(),
            qp: QpPool::new(qp_count.max(1)),
            numa_node,
            threads: threads.max(1),
            timing,
            chunk_bytes,
            materialized: FxHashMap::default(),
            stats: HostStats::default(),
            trace: None,
            max_batch_pages: Self::DEFAULT_MAX_BATCH_PAGES,
            coalesce_fetch: true,
            fetch_scratch: Vec::new(),
            span_keys: Vec::new(),
            miss_queues: MissQueues::default(),
            miss_used: Vec::new(),
            pending_writebacks: Vec::new(),
            host_workers: 1,
            base_qp_count: qp_count.max(1),
            lane_clocks: vec![0],
            lane_spans: Vec::new(),
            pushdown: PushdownMode::Off,
        }
    }

    /// Default batched-fault window (pages) — matches the DPU's task-batch
    /// SQ depth (`DpuConfig::max_batch`).
    pub const DEFAULT_MAX_BATCH_PAGES: u64 = 16;

    /// Configure the batched fault engine: `max_batch_pages` caps the pages
    /// handled per fault window (1 restores the seed's per-page path);
    /// `coalesce` merges contiguous misses into multi-page range requests.
    pub fn set_fetch_batch(&mut self, max_batch_pages: u64, coalesce: bool) {
        self.max_batch_pages = max_batch_pages.max(1);
        self.coalesce_fetch = coalesce;
    }

    /// Current `(max_batch_pages, coalesce)` knobs of the fault engine.
    pub fn fetch_batch(&self) -> (u64, bool) {
        (self.max_batch_pages, self.coalesce_fetch)
    }

    /// Configure W concurrent host fault workers. Must be applied before
    /// any traffic (the service sets it at client construction, like
    /// [`PageBuffer::set_shards`]). Rebuilds the QP pool to
    /// `qp_count * w` queues so each worker lane posts on its own QP; the
    /// pool's shared-contention condition (`contenders > queues`) is
    /// invariant in W, so the per-post cost model is unchanged. `w == 1`
    /// keeps every path bit-identical to the serial agent.
    pub fn set_host_workers(&mut self, workers: usize) {
        let w = workers.max(1);
        assert_eq!(
            self.qp.total_posted(),
            0,
            "set_host_workers on an agent with traffic"
        );
        self.host_workers = w;
        self.qp = QpPool::new(self.base_qp_count * w);
        self.lane_clocks = vec![0; w];
    }

    /// Concurrent host fault workers (W).
    pub fn host_workers(&self) -> usize {
        self.host_workers
    }

    /// Shard the page buffer's residency table P ways (see
    /// [`PageBuffer::set_shards`]; must be applied before traffic).
    pub fn set_buffer_shards(&mut self, shards: usize) {
        self.buffer.set_shards(shards);
    }

    /// Page-buffer shard count (P).
    pub fn buffer_shards(&self) -> usize {
        self.buffer.shards()
    }

    /// Worker lane serving `key`: the buffer's shard hash over W buckets,
    /// so a page's lane assignment and shard assignment stay aligned.
    fn lane_of(&self, key: PageKey) -> usize {
        shard_index(key, self.host_workers)
    }

    /// Join the background eviction lanes into the caller's clock. The
    /// `flush` barrier (and everything downstream of it) must not complete
    /// before offloaded writebacks have retired.
    fn join_lanes(&self, now: Ns) -> Ns {
        self.lane_clocks.iter().fold(now, |t, &c| t.max(c))
    }

    /// QP post cost of a single-page fetch. The serial agent posts on the
    /// faulting thread's QP (the seed path); with W workers the post goes
    /// out on the page's lane QP. The modeled cost is identical either
    /// way — only which queue's counters tick differs.
    fn post_one_cost(&mut self, tid: usize, key: PageKey) -> Ns {
        let w = self.host_workers;
        if w <= 1 {
            return self.qp.post_cost_ns(tid, self.threads, 1);
        }
        let lane = self.lane_of(key);
        self.qp.post_cost_ns(tid * w + lane, self.threads * w, 1)
    }

    /// QP post cost of a window's coalesced span list. One worker: the
    /// seed's single post of every span on the faulting thread's QP. W
    /// workers: the spans partition across worker lanes by the shard hash
    /// of each span's start (coalesced runs are shard-local, so a run maps
    /// to one lane), each lane posts its sub-batch on its own QP, and the
    /// window waits for the *slowest lane* — max over lanes instead of the
    /// serial sum. Each active lane rings its own doorbell, so
    /// `qp_doorbells` can exceed the serial count at W > 1; WQE totals and
    /// bytes-on-wire are identical at any W.
    fn post_spans_cost(&mut self, tid: usize, spans: &[PageSpan]) -> Ns {
        let w = self.host_workers;
        if w <= 1 {
            return self.qp.post_cost_ns(tid, self.threads, spans.len() as u64);
        }
        let mut counts = std::mem::take(&mut self.lane_spans);
        counts.clear();
        counts.resize(w, 0);
        for s in spans {
            counts[self.lane_of(s.start)] += 1;
        }
        let mut worst = 0;
        for (lane, &n) in counts.iter().enumerate() {
            if n > 0 {
                worst = worst.max(self.qp.post_cost_ns(tid * w + lane, self.threads * w, n));
            }
        }
        self.lane_spans = counts;
        worst
    }

    /// Start recording the miss (fault) trace.
    pub fn enable_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Take the recorded trace (stops recording).
    pub fn take_trace(&mut self) -> Vec<(Ns, PageKey)> {
        self.trace.take().unwrap_or_default()
    }

    pub fn chunk_bytes(&self) -> u64 {
        self.chunk_bytes
    }

    pub fn buffer_stats(&self) -> BufferStats {
        self.buffer.stats()
    }

    /// Direct access to the page buffer for state inspection (equivalence
    /// tests fingerprint resident pages and dirty state through this).
    pub fn buffer_mut(&mut self) -> &mut PageBuffer {
        &mut self.buffer
    }

    pub fn stats(&self) -> HostStats {
        let mut s = self.stats;
        s.qp_posted = self.qp.total_posted();
        s.qp_doorbells = self.qp.total_doorbells();
        s.qp_over_completions = self.qp.total_over_completions();
        s
    }

    pub fn store_name(&self) -> &'static str {
        self.store.name()
    }

    pub fn object(&self, name: &str) -> Option<FamHandle> {
        self.objects.get(name)
    }

    fn mark_materialized(&mut self, key: PageKey) {
        let bits = self.materialized.entry(key.region).or_default();
        let word = (key.page / 64) as usize;
        if bits.len() <= word {
            bits.resize(word + 1, 0);
        }
        bits[word] |= 1 << (key.page % 64);
    }

    fn is_materialized(&self, key: PageKey) -> bool {
        self.materialized
            .get(&key.region)
            .map(|bits| {
                let word = (key.page / 64) as usize;
                word < bits.len() && bits[word] & (1 << (key.page % 64)) != 0
            })
            .unwrap_or(false)
    }

    fn mark_region_materialized(&mut self, region: RegionId, pages: u64) {
        let words = (pages as usize).div_ceil(64);
        self.materialized.insert(region, vec![u64::MAX; words]);
    }

    /// `SODA_alloc`: create a FAM-backed object. `file` pre-loads server-side
    /// data (its pages are immediately materialized); anonymous objects
    /// zero-fill on first touch. Returns the handle and completion time, or
    /// the memory node's structured refusal (e.g.
    /// [`MemError::OutOfCapacity`]) — the agent stays fully usable after a
    /// refused allocation.
    pub fn try_alloc(
        &mut self,
        now: Ns,
        name: impl Into<String>,
        bytes: u64,
        file: Option<Vec<u8>>,
        placement: Placement,
    ) -> Result<(FamHandle, Ns), MemError> {
        let file_backed = file.is_some();
        let (region, done) = self.store.try_alloc(now, bytes, file)?;
        let handle = FamHandle {
            region,
            bytes,
            placement,
            writable: true,
        };
        if file_backed {
            self.mark_region_materialized(region, handle.pages(self.chunk_bytes));
        }
        self.objects.insert(name, handle);
        Ok((handle, done))
    }

    /// Infallible convenience wrapper around [`Self::try_alloc`] for
    /// callers that treat allocation failure as a programming error.
    pub fn alloc(
        &mut self,
        now: Ns,
        name: impl Into<String>,
        bytes: u64,
        file: Option<Vec<u8>>,
        placement: Placement,
    ) -> (FamHandle, Ns) {
        self.try_alloc(now, name, bytes, file, placement)
            .expect("region allocation")
    }

    /// Map an object another process allocated (read-only sharing; §III
    /// restricts writable mappings to single clients).
    pub fn map_shared(&mut self, name: impl Into<String>, mut handle: FamHandle) -> FamHandle {
        handle.writable = false;
        self.mark_region_materialized(handle.region, handle.pages(self.chunk_bytes));
        self.objects.insert(name, handle);
        handle
    }

    /// Free an object and its region.
    pub fn dealloc(&mut self, now: Ns, name: &str) -> Option<Ns> {
        let handle = self.objects.remove(name)?;
        self.materialized.remove(&handle.region);
        Some(self.store.free(now, handle.region))
    }

    /// Proactive eviction: keep the buffer under its threshold; dirty
    /// chunks are written back (the store decides whether the host blocks
    /// for durability or is released at DPU hand-off).
    fn evict_for_insert(&mut self, mut t: Ns) -> Ns {
        while self.buffer.over_threshold() || self.buffer.is_full() {
            let Some(ev) = self.buffer.evict_lru() else { break };
            if self.host_workers > 1 {
                // Offloaded: the page's background worker lane absorbs the
                // management and writeback time; the faulting thread does
                // not wait. The store calls still happen in program order
                // (coherence in the simulation is order-based, not
                // timestamp-based), so bytes-on-wire and final store state
                // match the serial agent — only the clock charged differs.
                let lane = self.lane_of(ev.key);
                let lane_t = self.lane_clocks[lane].max(t) + self.timing.evict_mgmt_ns;
                if ev.dirty {
                    match self.store.try_writeback(lane_t, ev.key, &ev.data) {
                        Ok(released) => {
                            self.mark_materialized(ev.key);
                            self.stats.writebacks += 1;
                            self.lane_clocks[lane] = released;
                        }
                        Err(_) => {
                            self.stats.writeback_requeues += 1;
                            self.lane_clocks[lane] = lane_t;
                            self.pending_writebacks.push((ev.key, ev.data));
                            continue;
                        }
                    }
                } else {
                    self.lane_clocks[lane] = lane_t;
                }
                self.buffer.recycle(ev.data);
                continue;
            }
            t += self.timing.evict_mgmt_ns;
            if ev.dirty {
                match self.store.try_writeback(t, ev.key, &ev.data) {
                    Ok(released) => {
                        self.mark_materialized(ev.key);
                        self.stats.writebacks += 1;
                        t = released;
                    }
                    Err(_) => {
                        // Durability: the store did NOT take the page. Park
                        // the bytes for a later retry instead of silently
                        // losing the write.
                        self.stats.writeback_requeues += 1;
                        self.pending_writebacks.push((ev.key, ev.data));
                        continue;
                    }
                }
            }
            self.buffer.recycle(ev.data);
        }
        self.drain_pending(t)
    }

    /// Retry parked writebacks with the bounded budget; pages that fail
    /// again go back to the queue (the flush barrier clears them for
    /// certain). No-op when nothing is parked — the fault-free fast path.
    fn drain_pending(&mut self, mut t: Ns) -> Ns {
        if self.pending_writebacks.is_empty() {
            return t;
        }
        let pending = std::mem::take(&mut self.pending_writebacks);
        for (key, data) in pending {
            match self.store.try_writeback(t, key, &data) {
                Ok(released) => {
                    self.mark_materialized(key);
                    self.stats.writebacks += 1;
                    t = released;
                    self.buffer.recycle(data);
                }
                Err(_) => {
                    self.stats.writeback_requeues += 1;
                    self.pending_writebacks.push((key, data));
                }
            }
        }
        t
    }

    /// Index of `key` in the parked-writeback queue, if present.
    fn pending_index(&self, key: PageKey) -> Option<usize> {
        if self.pending_writebacks.is_empty() {
            return None;
        }
        self.pending_writebacks.iter().position(|(k, _)| *k == key)
    }

    /// Restore a parked page into the buffer: its freshest bytes live only
    /// in the requeue, so a fault must serve from there (still dirty — the
    /// data has never reached durability), never re-fetch stale state.
    fn restore_pending(&mut self, idx: usize, key: PageKey) {
        let (_, data) = self.pending_writebacks.swap_remove(idx);
        self.buffer.insert_with(key, true, |d| d.copy_from_slice(&data));
        self.buffer.recycle(data);
    }

    /// The non-resident half of the per-page fault path: trap, evict as
    /// needed, then fetch (materialized) or zero-fill (anonymous first
    /// touch). The caller has already observed the miss via
    /// `buffer.access`.
    fn fault_one(&mut self, now: Ns, tid: usize, key: PageKey, write: bool) -> Ns {
        self.stats.faults += 1;
        if let Some(trace) = &mut self.trace {
            trace.push((now, key));
        }
        let mut t = now + self.timing.fault_trap_ns;
        t = self.evict_for_insert(t);
        if let Some(idx) = self.pending_index(key) {
            // Parked after a failed writeback: restore locally (the store
            // holds stale bytes), at local-copy cost, still dirty.
            self.restore_pending(idx, key);
            let done = t + self.timing.zero_fill_ns;
            self.stats.stall_ns += done.saturating_sub(now);
            return done;
        }
        if self.is_materialized(key) {
            // Post the request on this thread's QP (the page's lane QP at
            // W > 1) and fetch.
            t += self.post_one_cost(tid, key);
            let frame = self.buffer.insert_with(key, write, |_| {});
            let (done, src) = self.store.fetch(t, key, self.numa_node, frame);
            self.stats.count(src);
            self.stats.stall_ns += done.saturating_sub(now);
            done
        } else {
            // Anonymous first touch: local zero-fill, no remote traffic.
            self.buffer.insert_with(key, write, |d| d.fill(0));
            self.stats.zero_fills += 1;
            let done = t + self.timing.zero_fill_ns;
            self.stats.stall_ns += done.saturating_sub(now);
            done
        }
    }

    /// The page-fault path: ensure `key` is resident, return completion.
    pub fn touch_page(&mut self, now: Ns, tid: usize, key: PageKey, write: bool) -> Ns {
        if self.buffer.access(key, write).is_some() {
            return now + self.timing.hit_ns;
        }
        self.fault_one(now, tid, key, write)
    }

    /// Batched fault path: ensure every page of `keys` is resident,
    /// coalescing the misses into range requests posted with one doorbell
    /// and overlapping their round trips (see the module docs). Observably
    /// equivalent to calling [`Self::touch_page`] per key — identical final
    /// buffer state, fault counts and bytes-on-wire — but a k-miss window
    /// pays ~one round trip instead of k. Returns the completion time.
    pub fn touch_pages(&mut self, now: Ns, tid: usize, keys: &[PageKey], write: bool) -> Ns {
        self.touch_span(now, tid, keys, write, &mut |_, _| {})
    }

    /// Window-split driver shared by [`Self::touch_pages`] and the byte
    /// spans: processes `keys` in `max_batch_pages`-sized fault windows,
    /// invoking `sink(index, frame)` with each page's resident frame (in
    /// key order) so callers copy bytes without a second buffer lookup.
    fn touch_span(
        &mut self,
        now: Ns,
        tid: usize,
        keys: &[PageKey],
        write: bool,
        sink: &mut dyn FnMut(usize, &mut [u8]),
    ) -> Ns {
        let window = self.max_batch_pages.max(1) as usize;
        let mut t = now;
        let mut i = 0;
        while i < keys.len() {
            let end = (i + window).min(keys.len());
            t = self.touch_window(t, tid, i, &keys[i..end], write, sink);
            i = end;
        }
        t
    }

    /// One fault window: a single batched residency pre-scan finds the
    /// misses that need the wire; windows with fewer than two such misses
    /// take the sequential path (bit-identical to the seed's per-page
    /// behavior), everything else goes through the batched engine.
    fn touch_window(
        &mut self,
        now: Ns,
        tid: usize,
        base_idx: usize,
        keys: &[PageKey],
        write: bool,
        sink: &mut dyn FnMut(usize, &mut [u8]),
    ) -> Ns {
        let mut mq = std::mem::take(&mut self.miss_queues);
        mq.begin();
        for &k in keys {
            if !self.buffer.is_resident(k)
                && self.is_materialized(k)
                && self.pending_index(k).is_none()
            {
                mq.note_miss(k);
            }
        }
        self.stats.miss_waiters += mq.total_waiters();
        let t_end = if mq.leaders.len() >= 2 {
            self.window_batched(now, tid, base_idx, keys, write, &mq.leaders, sink)
        } else {
            self.window_sequential(now, tid, base_idx, keys, write, sink)
        };
        self.miss_queues = mq;
        t_end
    }

    /// Per-page walk (0–1 fetchable misses in the window): the seed's
    /// sequential fault loop, minus the redundant post-touch buffer lookup.
    fn window_sequential(
        &mut self,
        now: Ns,
        tid: usize,
        base_idx: usize,
        keys: &[PageKey],
        write: bool,
        sink: &mut dyn FnMut(usize, &mut [u8]),
    ) -> Ns {
        let mut t = now;
        for (i, &key) in keys.iter().enumerate() {
            if let Some(frame) = self.buffer.access(key, write) {
                sink(base_idx + i, frame);
                t += self.timing.hit_ns;
                continue;
            }
            t = self.fault_one(t, tid, key, write);
            let frame = self.buffer.peek(key).expect("just faulted");
            sink(base_idx + i, frame);
        }
        t
    }

    /// The batched window: fetch the miss set up front (one trap, one
    /// doorbell, overlapped round trips), then replay the *exact*
    /// sequential per-page buffer operations — same access/evict/insert
    /// order ⇒ same final buffer state, with page data arriving from the
    /// prefetched staging scratch instead of k chained fetches.
    #[allow(clippy::too_many_arguments)]
    fn window_batched(
        &mut self,
        now: Ns,
        tid: usize,
        base_idx: usize,
        keys: &[PageKey],
        write: bool,
        miss: &[PageKey],
        sink: &mut dyn FnMut(usize, &mut [u8]),
    ) -> Ns {
        let chunk = self.chunk_bytes as usize;
        let spans = PageSpan::coalesce(miss, self.coalesce_fetch);
        // One trap covers the burst (the handler sees the whole faulting
        // range), then the miss set posts — one WQE per coalesced range
        // request, on one QP (serial agent) or partitioned across the
        // worker lanes' QPs (W > 1, window waits for the slowest lane).
        let mut t_wall = now + self.timing.fault_trap_ns;
        t_wall += self.post_spans_cost(tid, &spans);
        let total = miss.len() * chunk;
        let mut scratch = std::mem::take(&mut self.fetch_scratch);
        if scratch.len() < total {
            scratch.resize(total, 0);
        }
        let fetched = self
            .store
            .fetch_batch(t_wall, &spans, self.numa_node, &mut scratch[..total]);
        debug_assert_eq!(fetched.len(), miss.len());
        // Coalescing preserves key order, so scratch slot i holds miss[i].
        let mut miss_used = std::mem::take(&mut self.miss_used);
        miss_used.clear();
        miss_used.resize(miss.len(), false);
        // Misses are discovered in walk order, so each non-duplicate miss
        // is consumed at the cursor; the scan behind it only runs for the
        // rare duplicate/evicted-mid-window cases.
        let mut miss_cursor = 0usize;
        let mut t_data = t_wall;
        let mut hit_time = 0;
        for (i, &key) in keys.iter().enumerate() {
            if let Some(frame) = self.buffer.access(key, write) {
                sink(base_idx + i, frame);
                t_wall += self.timing.hit_ns;
                hit_time += self.timing.hit_ns;
                continue;
            }
            self.stats.faults += 1;
            if let Some(trace) = &mut self.trace {
                // Stamp with the page's own fault-processing time, like the
                // sequential path (the batch posts earlier, but the walk
                // reaches this page at t_wall).
                trace.push((t_wall, key));
            }
            t_wall = self.evict_for_insert(t_wall);
            let slot = if miss_cursor < miss.len()
                && miss[miss_cursor] == key
                && !miss_used[miss_cursor]
            {
                Some(miss_cursor)
            } else {
                miss.iter().position(|&m| m == key).filter(|&m| !miss_used[m])
            };
            if let Some(m) = slot {
                miss_used[m] = true;
                miss_cursor = miss_cursor.max(m + 1);
                let (done, src) = fetched[m];
                let data = &scratch[m * chunk..(m + 1) * chunk];
                let frame = self.buffer.insert_with(key, write, |d| d.copy_from_slice(data));
                self.stats.count(src);
                t_data = t_data.max(done);
                sink(base_idx + i, frame);
            } else if let Some(idx) = self.pending_index(key) {
                // Parked after a failed writeback: restore locally (the
                // store holds stale bytes), still dirty.
                self.restore_pending(idx, key);
                t_wall += self.timing.zero_fill_ns;
                let frame = self.buffer.peek(key).expect("just restored");
                sink(base_idx + i, frame);
            } else if self.is_materialized(key) {
                // Resident at the pre-scan (or already consumed) but missing
                // now — this very window evicted it. Fall back to the
                // sequential single fetch, exactly like the per-page loop.
                t_wall += self.post_one_cost(tid, key);
                {
                    let frame = self.buffer.insert_with(key, write, |_| {});
                    let (done, src) = self.store.fetch(t_wall, key, self.numa_node, frame);
                    self.stats.count(src);
                    t_data = t_data.max(done);
                }
                let frame = self.buffer.peek(key).expect("just inserted");
                sink(base_idx + i, frame);
            } else {
                // Anonymous first touch: local zero-fill, no remote traffic.
                self.stats.zero_fills += 1;
                t_wall += self.timing.zero_fill_ns;
                let frame = self.buffer.insert_with(key, write, |d| d.fill(0));
                sink(base_idx + i, frame);
            }
        }
        self.fetch_scratch = scratch;
        miss_used.clear();
        self.miss_used = miss_used;
        let end = t_wall.max(t_data);
        // The thread stalls once for the whole burst; per-page accounting
        // would double-count the overlapped round trips. Hit service time
        // is excluded, matching the sequential path's per-fault sum.
        self.stats.stall_ns += end.saturating_sub(now).saturating_sub(hit_time);
        end
    }

    /// Shared walk of a byte span's pages through the batched fault
    /// engine. `copy(buf_range, frame_range, frame)` moves bytes between
    /// the caller's buffer and each page's frame (direction is the
    /// caller's choice); the ranges are the span/page overlap clamped to
    /// the span's `[offset, offset + len)` window.
    #[allow(clippy::too_many_arguments)]
    fn span_bytes(
        &mut self,
        now: Ns,
        tid: usize,
        region: RegionId,
        offset: u64,
        len: u64,
        write: bool,
        copy: &mut dyn FnMut(std::ops::Range<usize>, std::ops::Range<usize>, &mut [u8]),
    ) -> Ns {
        let chunk = self.chunk_bytes;
        let first_page = offset / chunk;
        let last_page = (offset + len - 1) / chunk;
        let mut keys = std::mem::take(&mut self.span_keys);
        keys.clear();
        keys.extend((first_page..=last_page).map(|p| PageKey::new(region, p)));
        let t = self.touch_span(now, tid, &keys, write, &mut |idx, frame| {
            let page_start = (first_page + idx as u64) * chunk;
            let a = offset.max(page_start);
            let b = (offset + len).min(page_start + chunk);
            copy(
                (a - offset) as usize..(b - offset) as usize,
                (a - page_start) as usize..(b - page_start) as usize,
                frame,
            );
        });
        self.span_keys = keys;
        t
    }

    /// Read `out.len()` bytes at `offset` of a region, faulting as needed —
    /// the whole span goes through the batched fault engine, so the pages
    /// it misses travel as coalesced range requests.
    pub fn read_bytes(
        &mut self,
        now: Ns,
        tid: usize,
        region: RegionId,
        offset: u64,
        out: &mut [u8],
    ) -> Ns {
        if out.is_empty() {
            return now;
        }
        let len = out.len() as u64;
        self.span_bytes(now, tid, region, offset, len, false, &mut |buf, fr, frame| {
            out[buf].copy_from_slice(&frame[fr]);
        })
    }

    /// Write bytes at `offset`, faulting pages (read-modify-write) and
    /// marking them dirty. Missed pages of the span fetch as one batch.
    pub fn write_bytes(
        &mut self,
        now: Ns,
        tid: usize,
        region: RegionId,
        offset: u64,
        data: &[u8],
    ) -> Ns {
        if data.is_empty() {
            return now;
        }
        let len = data.len() as u64;
        self.span_bytes(now, tid, region, offset, len, true, &mut |buf, fr, frame| {
            frame[fr].copy_from_slice(&data[buf]);
        })
    }

    /// Does the backend's prefetcher consume application hints right now?
    /// (Lets callers skip frontier→span translation when nobody listens.)
    pub fn wants_prefetch_hints(&self) -> bool {
        self.store.wants_prefetch_hints()
    }

    /// Is the region pinned in the DPU static cache? (Static regions are
    /// served one-sided and bypass the dynamic cache — hinting them is
    /// pointless.)
    pub fn is_static(&self, region: RegionId) -> bool {
        self.store.is_static(region)
    }

    /// Post an application prefetch hint naming the page spans the next
    /// phase will read. Advisory and off the critical path: the caller's
    /// clock is not advanced — the wire transfer and DPU-side staging are
    /// charged inside the store on the background class. Pages already
    /// resident in the local buffer are filtered out first (they generate
    /// no demand, so staging them remotely would be pure waste). Returns
    /// whether a hint message was actually sent.
    pub fn prefetch_hint(&mut self, now: Ns, spans: &[PageSpan]) -> bool {
        if spans.is_empty() || !self.store.wants_prefetch_hints() {
            return false;
        }
        // The filter walk is O(hinted pages); when the hinted set dwarfs
        // the buffer (which holds every page the filter could remove),
        // filtering can trim under ~25% — skip the walk and let the
        // DPU-side residency dedup absorb the overlap instead. This keeps
        // whole-stream hints (PageRank's full edge array, every iteration)
        // off the host's hot loop.
        let hinted_pages: u64 = spans.iter().map(|s| s.pages).sum();
        if hinted_pages > 4 * (self.buffer.resident_pages() as u64).max(1) {
            let numa = self.numa_node;
            if self.store.prefetch_hint(now, spans, numa).is_some() {
                self.stats.hints_sent += 1;
                return true;
            }
            return false;
        }
        // Split each span at locally-resident pages, keeping the miss runs.
        // Residency splitting can fragment heavily, so the result is capped:
        // the tail simply goes unhinted (and faults on demand as usual).
        const MAX_FILTERED_SPANS: usize = 2048;
        let mut filtered: Vec<PageSpan> = Vec::new();
        'spans: for s in spans {
            let mut run_start: Option<u64> = None;
            for i in 0..s.pages {
                let key = s.key_at(i);
                if self.buffer.is_resident(key) {
                    if let Some(first) = run_start.take() {
                        filtered.push(PageSpan {
                            start: PageKey::new(s.start.region, first),
                            pages: s.start.page + i - first,
                        });
                        if filtered.len() >= MAX_FILTERED_SPANS {
                            break 'spans;
                        }
                    }
                } else if run_start.is_none() {
                    run_start = Some(s.start.page + i);
                }
            }
            if let Some(first) = run_start {
                filtered.push(PageSpan {
                    start: PageKey::new(s.start.region, first),
                    pages: s.start.page + s.pages - first,
                });
                if filtered.len() >= MAX_FILTERED_SPANS {
                    break 'spans;
                }
            }
        }
        if filtered.is_empty() {
            return false;
        }
        let numa = self.numa_node;
        if self.store.prefetch_hint(now, &filtered, numa).is_some() {
            self.stats.hints_sent += 1;
            true
        } else {
            false
        }
    }

    /// Set the operator-pushdown routing policy (applied by the service at
    /// client construction; safe to flip between supersteps).
    pub fn set_pushdown(&mut self, mode: PushdownMode) {
        self.pushdown = mode;
    }

    /// Current pushdown routing policy.
    pub fn pushdown_mode(&self) -> PushdownMode {
        self.pushdown
    }

    /// Is pushdown worth even *building* a descriptor for? True only when
    /// the policy allows it and the backend has near-data compute.
    pub fn supports_pushdown(&self) -> bool {
        self.pushdown != PushdownMode::Off && self.store.supports_pushdown()
    }

    /// Fraction of the spans' pages currently resident in the local page
    /// buffer — the [`PushdownMode::Auto`] probe: spans mostly resident
    /// host-side generate little demand traffic, so shipping a kernel for
    /// them would *add* bytes, not save them.
    pub fn resident_fraction(&self, spans: &[PageSpan]) -> f64 {
        let mut total = 0u64;
        let mut resident = 0u64;
        for s in spans {
            for i in 0..s.pages {
                total += 1;
                if self.buffer.is_resident(s.key_at(i)) {
                    resident += 1;
                }
            }
        }
        if total == 0 {
            return 0.0;
        }
        resident as f64 / total as f64
    }

    /// Record a host-side pushdown decline (the [`PushdownMode::Auto`]
    /// probe predicting a loss before any descriptor was built) so the
    /// ledger's fallback count covers both decision sites.
    pub fn note_pushdown_fallback(&mut self) {
        self.stats.pushdown_fallbacks += 1;
    }

    /// Ship a pushdown kernel descriptor to the backend and block until the
    /// reduced results land (`Some(done, results)`), or learn that the
    /// backend declined (`None`) — the caller must then run the same
    /// superstep over the paging path. On-critical-path, unlike hints: the
    /// superstep cannot proceed without the results.
    pub fn pushdown(
        &mut self,
        now: Ns,
        req: &crate::fabric::protocol::PushdownRequest,
    ) -> Option<(Ns, Vec<u8>)> {
        let numa = self.numa_node;
        match self.store.pushdown(now, req, numa) {
            Some(r) => {
                self.stats.pushdowns += 1;
                Some(r)
            }
            None => {
                self.stats.pushdown_fallbacks += 1;
                None
            }
        }
    }

    /// Flush all dirty pages to the store (barrier / pre-pin sync). Parked
    /// writebacks go out first on the *infallible* path — a flush is a
    /// durability barrier, so it may not leave requeued pages behind. The
    /// barrier also joins the background worker lanes: offloaded eviction
    /// writebacks must retire before the flush completes.
    pub fn flush(&mut self, now: Ns) -> Ns {
        let mut t = self.join_lanes(now);
        for (key, data) in std::mem::take(&mut self.pending_writebacks) {
            let released = self.store.writeback(t, key, &data);
            self.mark_materialized(key);
            self.stats.writebacks += 1;
            t = released;
            self.buffer.recycle(data);
        }
        for ev in self.buffer.drain_dirty() {
            let released = self.store.writeback(t, ev.key, &ev.data);
            self.mark_materialized(ev.key);
            self.stats.writebacks += 1;
            t = released;
            self.buffer.recycle(ev.data);
        }
        t
    }

    /// Pin an object into the DPU static cache (flushes first so the bulk
    /// load sees current data). No-op `None` on DPU-less backends.
    pub fn pin_static(&mut self, now: Ns, name: &str) -> Option<Ns> {
        let handle = self.objects.get(name)?;
        let t = self.flush(now);
        self.store.pin_static(t, handle.region)
    }

    /// Drop every resident page (cold-cache boundary between experiment
    /// phases; dirty pages are flushed first).
    pub fn invalidate_buffer(&mut self, now: Ns) -> Ns {
        let t = self.flush(now);
        while let Some(ev) = self.buffer.evict_lru() {
            debug_assert!(!ev.dirty);
            self.buffer.recycle(ev.data);
        }
        t
    }
}

impl std::fmt::Debug for HostAgent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HostAgent")
            .field("name", &self.name)
            .field("store", &self.store.name())
            .field("host_workers", &self.host_workers)
            .field("buffer_shards", &self.buffer.shards())
            .field("resident_pages", &self.buffer.resident_pages())
            .field("stats", &self.stats)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::MemServerStore;
    use crate::coordinator::cluster::Cluster;
    use crate::coordinator::config::ClusterConfig;

    fn agent_with_buffer_pages(pages: u64) -> (HostAgent, Cluster) {
        let cluster = Cluster::build(ClusterConfig::tiny());
        let chunk = cluster.config().chunk_bytes;
        let store = Box::new(MemServerStore::new(cluster.clone()));
        let agent = HostAgent::new(
            "p0",
            store,
            pages * chunk,
            chunk,
            1.0,
            4,
            4,
            2,
            HostTiming::default(),
        );
        (agent, cluster)
    }

    #[test]
    fn anonymous_first_touch_is_local_zero_fill() {
        let (mut a, cluster) = agent_with_buffer_pages(8);
        let (h, t0) = a.alloc(0, "x", 4 * a.chunk_bytes(), None, Placement::Default);
        cluster.reset_stats();
        let mut out = vec![0xFFu8; 16];
        a.read_bytes(t0, 0, h.region, 0, &mut out);
        assert!(out.iter().all(|&b| b == 0), "anon pages read as zero");
        assert_eq!(cluster.network_stats().on_demand_bytes(), 0, "no remote fetch");
        assert_eq!(a.stats().zero_fills, 1);
    }

    #[test]
    fn write_then_evict_then_read_roundtrips_through_memnode() {
        let (mut a, cluster) = agent_with_buffer_pages(2);
        let chunk = a.chunk_bytes();
        let (h, t0) = a.alloc(0, "x", 8 * chunk, None, Placement::Default);
        // Write distinct bytes to 4 pages; buffer holds only 2 → evictions.
        let mut t = t0;
        for p in 0..4u64 {
            let data = vec![p as u8 + 1; chunk as usize];
            t = a.write_bytes(t, 0, h.region, p * chunk, &data);
        }
        assert!(a.stats().writebacks >= 2, "dirty evictions happened");
        // Read back page 0 (evicted long ago) — must refetch real bytes.
        let mut out = vec![0u8; chunk as usize];
        a.read_bytes(t, 0, h.region, 0, &mut out);
        assert!(out.iter().all(|&b| b == 1), "page 0 data survived eviction");
        assert!(cluster.network_stats().writeback_bytes() > 0);
    }

    #[test]
    fn buffer_hits_avoid_remote_traffic() {
        let (mut a, cluster) = agent_with_buffer_pages(8);
        let chunk = a.chunk_bytes();
        let file = vec![7u8; (2 * chunk) as usize];
        let (h, t0) = a.alloc(0, "f", 2 * chunk, Some(file), Placement::Default);
        let mut out = vec![0u8; 64];
        let t1 = a.read_bytes(t0, 0, h.region, 0, &mut out);
        let before = cluster.network_stats().on_demand_bytes();
        let t2 = a.read_bytes(t1, 0, h.region, 8, &mut out);
        assert_eq!(cluster.network_stats().on_demand_bytes(), before, "hit: no traffic");
        assert!(t2 - t1 < 1_000, "hit latency is sub-µs");
    }

    #[test]
    fn read_spanning_pages() {
        let (mut a, _cluster) = agent_with_buffer_pages(8);
        let chunk = a.chunk_bytes();
        let mut file = vec![0u8; (2 * chunk) as usize];
        file[chunk as usize - 1] = 1;
        file[chunk as usize] = 2;
        let (h, t0) = a.alloc(0, "f", 2 * chunk, Some(file), Placement::Default);
        let mut out = [0u8; 2];
        a.read_bytes(t0, 0, h.region, chunk - 1, &mut out);
        assert_eq!(out, [1, 2]);
        assert_eq!(a.stats().faults, 2, "two pages faulted");
    }

    #[test]
    fn flush_makes_data_durable_without_eviction() {
        let (mut a, _c) = agent_with_buffer_pages(8);
        let chunk = a.chunk_bytes();
        let (h, t0) = a.alloc(0, "x", 2 * chunk, None, Placement::Default);
        let data = vec![9u8; chunk as usize];
        let t1 = a.write_bytes(t0, 0, h.region, 0, &data);
        let t2 = a.flush(t1);
        assert!(t2 > t1);
        assert_eq!(a.stats().writebacks, 1);
        // Invalidate and re-read: the data must come back from the store.
        let t3 = a.invalidate_buffer(t2);
        let mut out = vec![0u8; chunk as usize];
        a.read_bytes(t3, 0, h.region, 0, &mut out);
        assert!(out.iter().all(|&b| b == 9));
    }

    #[test]
    fn dealloc_frees_the_region() {
        let (mut a, cluster) = agent_with_buffer_pages(4);
        let (_, t0) = a.alloc(0, "x", 4096, None, Placement::Default);
        let used_before = cluster.with(|i| i.memnode.store.used());
        assert!(used_before > 0);
        a.dealloc(t0, "x").expect("object exists");
        assert_eq!(cluster.with(|i| i.memnode.store.used()), 0);
        assert!(a.object("x").is_none());
    }

    #[test]
    fn stall_accounting_accumulates() {
        let (mut a, _c) = agent_with_buffer_pages(4);
        let chunk = a.chunk_bytes();
        let (h, t0) = a.alloc(0, "f", chunk, Some(vec![1; chunk as usize]), Placement::Default);
        let mut out = vec![0u8; 8];
        a.read_bytes(t0, 0, h.region, 0, &mut out);
        assert!(a.stats().stall_ns > 0);
        assert_eq!(a.stats().fetched(FetchSource::MemNode), 1);
    }

    /// Regression (batching satellite): a cold multi-page span must charge
    /// stall once per unit of elapsed fault time — the per-page terms
    /// telescope to `end - start`. Charging each page against the span's
    /// original start would multiply the stall by the page count.
    #[test]
    fn multi_page_span_stall_is_not_double_counted() {
        for batch in [1u64, 8] {
            let (mut a, _c) = agent_with_buffer_pages(16);
            a.set_fetch_batch(batch, true);
            let chunk = a.chunk_bytes();
            let pages = 6u64;
            let (h, t0) = a.alloc(
                0,
                "f",
                pages * chunk,
                Some(vec![2; (pages * chunk) as usize]),
                Placement::Default,
            );
            let mut out = vec![0u8; (pages * chunk) as usize];
            let t1 = a.read_bytes(t0, 0, h.region, 0, &mut out);
            assert_eq!(a.stats().faults, pages, "batch={batch}");
            assert_eq!(
                a.stats().stall_ns,
                t1 - t0,
                "batch={batch}: pure-miss span stall must equal elapsed fault time"
            );
        }
    }

    /// Mixed windows (hits interleaved with misses) must not fold hit
    /// service time into the stall sum — the sequential path only ever
    /// counts per-fault latencies.
    #[test]
    fn mixed_window_stall_excludes_hit_service_time() {
        let cluster = Cluster::build(ClusterConfig::tiny());
        let chunk = cluster.config().chunk_bytes;
        let store = Box::new(MemServerStore::new(cluster.clone()));
        let mut a = HostAgent::new(
            "p0",
            store,
            16 * chunk,
            chunk,
            1.0,
            4,
            4,
            2,
            HostTiming { hit_ns: 100, ..HostTiming::default() },
        );
        a.set_fetch_batch(8, true);
        let (h, t0) = a.alloc(0, "f", 6 * chunk, Some(vec![3; (6 * chunk) as usize]), Placement::Default);
        // Warm pages 0-2, then read a window of 3 hits + 3 misses.
        let mut warm = vec![0u8; (3 * chunk) as usize];
        let t1 = a.read_bytes(t0, 0, h.region, 0, &mut warm);
        let stall1 = a.stats().stall_ns;
        let mut out = vec![0u8; (6 * chunk) as usize];
        let t2 = a.read_bytes(t1, 0, h.region, 0, &mut out);
        assert!(out.iter().all(|&b| b == 3));
        assert_eq!(
            a.stats().stall_ns - stall1,
            (t2 - t1) - 3 * 100,
            "stall must exclude the 3 hits' service time"
        );
    }

    // ---- hint channel ---------------------------------------------------

    #[test]
    fn prefetch_hint_filters_resident_pages_and_counts_sends() {
        use crate::backend::DpuStore;
        use crate::host::PageSpan;
        let mut ccfg = ClusterConfig::tiny();
        ccfg.dpu.prefetch.policy = crate::dpu::PrefetchPolicyKind::GraphHint;
        let cluster = Cluster::build(ccfg);
        let chunk = cluster.config().chunk_bytes;
        let mut a = HostAgent::new(
            "p0",
            Box::new(DpuStore::new(cluster.clone())),
            48 * chunk, // roomy: the warm read must stay fully resident
            chunk,
            1.0,
            4,
            4,
            2,
            HostTiming::default(),
        );
        let ppe = cluster.config().dpu.cache_entry_bytes / chunk;
        let pages = 4 * ppe;
        let (h, t0) = a.alloc(
            0,
            "f",
            pages * chunk,
            Some(vec![3; (pages * chunk) as usize]),
            Placement::Default,
        );
        assert!(a.wants_prefetch_hints());
        assert!(!a.is_static(h.region));
        // Make the first entry's pages host-resident: hinting the whole
        // region must stage only the remaining entries.
        let mut warm = vec![0u8; (ppe * chunk) as usize];
        let t1 = a.read_bytes(t0, 0, h.region, 0, &mut warm);
        let staged_before = cluster.dpu_stats().prefetch_entries;
        assert!(a.prefetch_hint(t1, &[PageSpan { start: PageKey::new(h.region, 0), pages }]));
        assert_eq!(a.stats().hints_sent, 1);
        let hinted = cluster.dpu_stats().hint_entries;
        assert!(hinted >= 1, "non-resident tail must be hinted");
        assert!(
            hinted <= 3,
            "host-resident first entry must be filtered out ({hinted} entries hinted)"
        );
        assert!(cluster.dpu_stats().prefetch_entries > staged_before);
        // Empty and all-resident hints send nothing.
        assert!(!a.prefetch_hint(t1, &[]));
        assert!(!a.prefetch_hint(t1, &[PageSpan { start: PageKey::new(h.region, 0), pages: 1 }]));
        assert_eq!(a.stats().hints_sent, 1);
    }

    // ---- batched fault engine ------------------------------------------

    #[test]
    fn touch_pages_is_equivalent_to_per_page_loop() {
        // Same ops on twin clusters: batch=8 vs the sequential per-page
        // path. Buffer state, counters and traffic must match exactly.
        let (mut seq, c_seq) = agent_with_buffer_pages(8);
        let (mut bat, c_bat) = agent_with_buffer_pages(8);
        seq.set_fetch_batch(1, false);
        bat.set_fetch_batch(8, true);
        let chunk = seq.chunk_bytes();
        let file: Vec<u8> = (0..24 * chunk).map(|i| (i % 251) as u8).collect();
        let (h1, u0) = seq.alloc(0, "f", 24 * chunk, Some(file.clone()), Placement::Default);
        let (h2, v0) = bat.alloc(0, "f", 24 * chunk, Some(file), Placement::Default);
        c_seq.reset_stats();
        c_bat.reset_stats();
        // Mixed spans: contiguous run, overlap (re-hits), scattered pages.
        let spans: [(u64, usize); 4] =
            [(0, 6 * chunk as usize), (2 * chunk as usize, 8 * chunk as usize), (20 * chunk as usize, chunk as usize), (9 * chunk as usize, 3)];
        let (mut u, mut v) = (u0, v0);
        for &(off, len) in &spans {
            let mut o1 = vec![0u8; len];
            let mut o2 = vec![0u8; len];
            u = seq.read_bytes(u, 0, h1.region, off as u64, &mut o1);
            v = bat.read_bytes(v, 0, h2.region, off as u64, &mut o2);
            assert_eq!(o1, o2, "span ({off}, {len})");
        }
        let (s1, s2) = (seq.stats(), bat.stats());
        assert_eq!(s1.faults, s2.faults);
        assert_eq!(s1.sources, s2.sources);
        assert_eq!(seq.buffer_stats().hits, bat.buffer_stats().hits);
        assert_eq!(seq.buffer_stats().misses, bat.buffer_stats().misses);
        assert_eq!(
            c_seq.network_stats().network_bytes(),
            c_bat.network_stats().network_bytes(),
            "batching must not alter data-plane traffic"
        );
        assert!(
            s2.qp_doorbells < s1.qp_doorbells,
            "one doorbell per window beats one per page ({} vs {})",
            s2.qp_doorbells,
            s1.qp_doorbells
        );
        assert!(v - v0 <= u - u0, "batched span must not be slower");
    }

    #[test]
    fn batched_cold_span_beats_sequential_latency() {
        let (mut seq, _c1) = agent_with_buffer_pages(32);
        let (mut bat, _c2) = agent_with_buffer_pages(32);
        seq.set_fetch_batch(1, false);
        bat.set_fetch_batch(16, true);
        let chunk = seq.chunk_bytes();
        let file = vec![7u8; (16 * chunk) as usize];
        let (h1, u0) = seq.alloc(0, "f", 16 * chunk, Some(file.clone()), Placement::Default);
        let (h2, v0) = bat.alloc(0, "f", 16 * chunk, Some(file), Placement::Default);
        let mut out = vec![0u8; (16 * chunk) as usize];
        let u = seq.read_bytes(u0, 0, h1.region, 0, &mut out);
        let v = bat.read_bytes(v0, 0, h2.region, 0, &mut out);
        assert!(out.iter().all(|&b| b == 7));
        assert!(
            (v - v0) * 2 < u - u0,
            "a 16-page cold span must overlap round trips (batched {} vs sequential {})",
            v - v0,
            u - u0
        );
    }

    #[test]
    fn touch_pages_handles_duplicates_and_empty() {
        let (mut a, _c) = agent_with_buffer_pages(8);
        let chunk = a.chunk_bytes();
        let (h, t0) = a.alloc(0, "f", 4 * chunk, Some(vec![1; (4 * chunk) as usize]), Placement::Default);
        assert_eq!(a.touch_pages(t0, 0, &[], false), t0);
        let keys = [
            PageKey::new(h.region, 0),
            PageKey::new(h.region, 1),
            PageKey::new(h.region, 0), // duplicate: second occurrence hits
        ];
        let t1 = a.touch_pages(t0, 0, &keys, false);
        assert_eq!(a.stats().faults, 2, "duplicate pages fetch once");
        assert_eq!(a.buffer_stats().hits, 1);
        // Out-of-order duplicates (breaks the sorted dedup fast path).
        let keys = [
            PageKey::new(h.region, 3),
            PageKey::new(h.region, 2),
            PageKey::new(h.region, 3),
        ];
        a.touch_pages(t1, 0, &keys, false);
        assert_eq!(a.stats().faults, 4, "unsorted duplicate still fetches once");
        assert_eq!(a.buffer_stats().hits, 2);
    }

    /// Writeback durability under fault injection: a bounded writeback that
    /// exhausts its budget parks the page (requeue) instead of losing it,
    /// faults on the parked page restore the fresh bytes locally, and the
    /// flush barrier lands everything once the fault clears.
    #[test]
    fn failed_writeback_requeues_and_restores_locally() {
        use crate::backend::DpuStore;
        use crate::sim::fault::FaultConfig;
        let mut ccfg = ClusterConfig::tiny();
        ccfg.fault = FaultConfig {
            crash_start_ns: 0,
            crash_len_ns: 2_000_000,
            seed: 13,
            ..FaultConfig::default()
        };
        let cluster = Cluster::build(ccfg);
        let chunk = cluster.config().chunk_bytes;
        let mut a = HostAgent::new(
            "p0",
            Box::new(DpuStore::new(cluster.clone())),
            2 * chunk, // tiny buffer forces dirty eviction mid-crash
            chunk,
            1.0,
            4,
            4,
            2,
            HostTiming::default(),
        );
        let (h, t0) = a.alloc(0, "x", 4 * chunk, None, Placement::Default);
        let mut t = t0;
        for p in 0..3u64 {
            let data = vec![p as u8 + 1; chunk as usize];
            t = a.write_bytes(t, 0, h.region, p * chunk, &data);
        }
        assert!(a.stats().writeback_requeues > 0, "crash window must park pages");
        assert_eq!(a.stats().writebacks, 0, "nothing reached the store yet");
        // Faulting a parked page restores its bytes locally — the store
        // holds nothing for it, so a refetch would return stale zeros.
        let mut out = vec![0u8; chunk as usize];
        t = a.read_bytes(t, 0, h.region, 0, &mut out);
        assert!(out.iter().all(|&b| b == 1), "parked page restores its bytes");
        let t_flush = a.flush(t);
        assert!(t_flush > 2_000_000, "flush had to wait out the crash window");
        let t_inv = a.invalidate_buffer(t_flush);
        let mut back = vec![0u8; chunk as usize];
        a.read_bytes(t_inv, 0, h.region, 2 * chunk, &mut back);
        assert!(back.iter().all(|&b| b == 3), "requeued page became durable");
    }

    #[test]
    fn batched_write_span_round_trips_through_eviction() {
        // Batched writes mark pages dirty; a tiny buffer forces the window
        // to evict its own pages mid-walk and the data must survive.
        let (mut a, _c) = agent_with_buffer_pages(3);
        a.set_fetch_batch(8, true);
        let chunk = a.chunk_bytes();
        let pages = 8u64;
        let (h, t0) = a.alloc(0, "x", pages * chunk, None, Placement::Default);
        let data: Vec<u8> = (0..pages * chunk).map(|i| (i / chunk) as u8 + 1).collect();
        let t1 = a.write_bytes(t0, 0, h.region, 0, &data);
        assert!(a.stats().writebacks > 0, "3-page buffer must write back");
        let t2 = a.flush(t1);
        let mut out = vec![0u8; (pages * chunk) as usize];
        a.read_bytes(t2, 0, h.region, 0, &mut out);
        assert_eq!(out, data, "batched dirty spans survive eviction");
    }

    /// Write-heavy two-pass sweep of 16 pages through a 4-page buffer:
    /// every eviction is dirty, so the serial agent pays each writeback's
    /// wire time on the fault critical path while the multi-worker agent
    /// retires it on background lanes. Returns the data read back after a
    /// flush + invalidate round trip and the final completion time.
    fn scaling_workload(a: &mut HostAgent) -> (Vec<u8>, Ns) {
        a.set_fetch_batch(8, true);
        let chunk = a.chunk_bytes();
        let pages = 16u64;
        let (h, t0) = a.alloc(0, "x", pages * chunk, None, Placement::Default);
        let mut t = t0;
        for pass in 0..2u64 {
            for p in 0..pages {
                let data = vec![(pass * pages + p) as u8 + 1; chunk as usize];
                t = a.write_bytes(t, 0, h.region, p * chunk, &data);
            }
        }
        t = a.flush(t);
        let t_end = t;
        let t = a.invalidate_buffer(t);
        let mut out = vec![0u8; (pages * chunk) as usize];
        a.read_bytes(t, 0, h.region, 0, &mut out);
        (out, t_end)
    }

    #[test]
    fn multi_worker_matches_serial_observables_and_cuts_stall() {
        let (mut serial, c1) = agent_with_buffer_pages(4);
        let (mut wide, c2) = agent_with_buffer_pages(4);
        wide.set_buffer_shards(4);
        wide.set_host_workers(4);
        let (out1, t1) = scaling_workload(&mut serial);
        let (out4, t4) = scaling_workload(&mut wide);
        assert_eq!(out1, out4, "data is identical at any W");
        let s1 = serial.stats();
        let s4 = wide.stats();
        assert_eq!(s1.faults, s4.faults, "same fault count at any W");
        assert_eq!(s1.zero_fills, s4.zero_fills);
        assert_eq!(s1.writebacks, s4.writebacks);
        assert_eq!(s1.sources, s4.sources);
        assert_eq!(s1.qp_posted, s4.qp_posted, "same WQE total at any W");
        assert_eq!(
            c1.network_stats().on_demand_bytes(),
            c2.network_stats().on_demand_bytes(),
            "bytes-on-wire identical at any W"
        );
        assert_eq!(
            c1.network_stats().writeback_bytes(),
            c2.network_stats().writeback_bytes(),
            "writeback bytes identical at any W"
        );
        assert!(
            s4.stall_ns < s1.stall_ns,
            "4 workers must stall less ({} vs {})",
            s4.stall_ns,
            s1.stall_ns
        );
        assert!(t4 < t1, "4 workers must finish sooner ({t4} vs {t1})");
    }

    #[test]
    fn single_worker_single_shard_is_the_default() {
        let (a, _c) = agent_with_buffer_pages(4);
        assert_eq!(a.host_workers(), 1);
        assert_eq!(a.buffer_shards(), 1);
    }

    #[test]
    #[should_panic(expected = "set_host_workers on an agent with traffic")]
    fn worker_count_is_frozen_after_traffic() {
        let (mut a, _c) = agent_with_buffer_pages(8);
        let chunk = a.chunk_bytes();
        let file = vec![1u8; chunk as usize];
        let (h, t0) = a.alloc(0, "f", chunk, Some(file), Placement::Default);
        let mut out = vec![0u8; chunk as usize];
        a.read_bytes(t0, 0, h.region, 0, &mut out); // posts a WQE
        a.set_host_workers(2);
    }

    #[test]
    fn duplicate_window_misses_coalesce_as_waiters() {
        let (mut a, cluster) = agent_with_buffer_pages(8);
        a.set_fetch_batch(8, true);
        let chunk = a.chunk_bytes();
        let file = vec![5u8; (4 * chunk) as usize];
        let (h, t0) = a.alloc(0, "f", 4 * chunk, Some(file), Placement::Default);
        cluster.reset_stats();
        let keys = [
            PageKey::new(h.region, 0),
            PageKey::new(h.region, 2),
            PageKey::new(h.region, 0),
            PageKey::new(h.region, 2),
        ];
        a.touch_pages(t0, 0, &keys, false);
        let s = a.stats();
        assert_eq!(s.faults, 2, "one fetch per distinct page");
        assert_eq!(s.miss_waiters, 2, "duplicates joined the leaders' waiter lists");
        assert_eq!(
            cluster.network_stats().on_demand_bytes(),
            2 * chunk,
            "waiters generate no wire traffic"
        );
    }
}
