//! Offline stub of the `xla` PJRT bindings.
//!
//! The build environment has no XLA/PJRT shared libraries, so this crate
//! provides the exact type/function surface `soda::runtime` compiles
//! against, with every operation that would touch PJRT returning a clear
//! [`Error`] at call time. The AOT artifacts (HLO text produced by the
//! Python layer) still parse-side validate through `soda::runtime`'s
//! manifest handling; only execution requires swapping this stub for the
//! real bindings in Cargo.toml.

use std::fmt;

/// Error raised by every stubbed PJRT operation.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT is unavailable in this offline build (vendor/xla stub); \
         swap in the real xla bindings to execute artifacts"
    )))
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// PJRT client handle (stub: cannot be constructed).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub: parsing always fails).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable (stub: cannot be constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer (stub: cannot be constructed).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A host-side literal value.
#[derive(Debug, Default)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        unavailable("Literal::to_tuple2")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }

    #[test]
    fn literal_construction_is_permitted() {
        // Literal construction is cheap and infallible so call sites can
        // build argument lists before hitting the execute error.
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2, 1]).is_err());
        let _ = Literal::vec1(&[1i32]);
    }
}
