//! Offline drop-in shim for the `anyhow` error crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides the exact API subset SODA-RS uses: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros and the [`Context`] extension
//! trait. Errors are message chains (no backtraces, no downcasting) —
//! enough for CLI diagnostics, and source-compatible with the real crate
//! so it can be swapped back in by editing Cargo.toml alone.

use std::fmt;

/// A type-erased error: a human-readable message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from anything printable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
        }
    }

    /// Prepend context, like the real crate's `Error::context`.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors the real anyhow: Error deliberately does NOT implement
// std::error::Error, so this blanket conversion cannot overlap with the
// reflexive `From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("boom"));
    }

    #[test]
    fn context_chains_messages() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: boom");
        let e = io_fail()
            .with_context(|| format!("pass {}", 2))
            .unwrap_err();
        assert_eq!(e.to_string(), "pass 2: boom");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(inner(7).unwrap_err().to_string(), "unlucky 7");
        assert_eq!(inner(12).unwrap_err().to_string(), "x too big: 12");
        let e = anyhow!("coded {}", 5);
        assert_eq!(e.to_string(), "coded 5");
    }
}
