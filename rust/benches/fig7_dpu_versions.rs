//! Bench: Fig 7 — the three network-attached versions end to end.
use soda::coordinator::config::{BackendKind, CachingMode};
use soda::graph::App;
use soda::util::bench::Bench;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let mut b = Bench::quick();
    b.section("fig7: MemServer / DPU-base / DPU-opt (scale 2e-4)");
    for (backend, caching) in [
        (BackendKind::MemServer, CachingMode::None),
        (BackendKind::DPU_BASE, CachingMode::None),
        (BackendKind::DPU_OPT, CachingMode::Static),
    ] {
        b.bench(format!("components/friendster/{}", backend.label()), || {
            let mut wb = Workbench::new(0.0002);
            wb.threads = 24;
            wb.run(&ExperimentSpec {
                app: App::Components,
                graph: "friendster",
                backend,
                caching,
            })
            .elapsed_ns
        });
    }
}
