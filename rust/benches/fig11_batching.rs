//! Bench: the batched fault engine's cumulative Fig 11 story — per-page
//! base vs doorbell batching vs the async pipeline vs range coalescing.
//! Reports wall-clock of the simulator runs; the virtual-time speedups
//! come from `soda figures fig11`.
use soda::figures::evaluation::fig11_configs;
use soda::graph::App;
use soda::util::bench::Bench;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let mut b = Bench::quick();
    b.section("fig11 batching: base -> +doorbell -> +async -> +coalesce (scale 2e-4)");
    // The same table fig11 runs; the first four entries are the cumulative
    // batching story (the caching columns are covered by fig11_breakdown).
    for app in [App::PageRank, App::Bfs] {
        for c in fig11_configs().iter().take(4) {
            b.bench(format!("{}/friendster/{}", app.name(), c.name), || {
                let mut wb = Workbench::new(0.0002);
                wb.threads = 24;
                wb.max_batch_pages = Some(c.batch);
                wb.coalesce_fetch = Some(c.coalesce);
                wb.run(&ExperimentSpec {
                    app,
                    graph: "friendster",
                    backend: c.backend,
                    caching: c.caching,
                })
                .elapsed_ns
            });
        }
    }
}
