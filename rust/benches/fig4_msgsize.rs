//! Bench: Fig 4 — message-size sweep through the intra-node model.
use soda::fabric::numa::{IntraOp, NumaModel};
use soda::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    b.section("fig4: bandwidth-vs-size interpolation");
    let m = NumaModel::default();
    for op in [IntraOp::DpuToHostSend, IntraOp::DmaWrite] {
        b.bench(format!("sweep 256B..8M {}", op.label()), || {
            let mut acc = 0.0;
            let mut s = 256u64;
            while s <= 8 << 20 {
                acc += m.bandwidth_gbps(op, 2, s);
                s <<= 1;
            }
            black_box(acc)
        });
    }
    b.bench("figures::fig4()", || soda::figures::fig4().lines.len());
}
