//! Bench: the memory-fleet sweep behind `abl-fleet` — wall-clock of the
//! simulator runs per topology (single node / 2-node striped / 4-node
//! contiguous / 4-node striped / 4-node striped + replica & crash
//! windows) on the streaming app (PageRank). The virtual-time results
//! come from `soda figures abl-fleet`; set `BENCH_JSON=<path>` to also
//! dump these wall-clock stats as a `BENCH_fleet.json` trajectory record.

use soda::coordinator::config::{BackendKind, CachingMode};
use soda::fleet::FleetConfig;
use soda::graph::App;
use soda::sim::fault::FaultConfig;
use soda::util::bench::Bench;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let mut b = Bench::quick();
    b.section("abl-fleet: nodes x placement x crash windows (scale 2e-4)");
    // (mem_nodes, stripe_pages, replicas, crash_len_ns) — the abl-fleet cells.
    let cells: [(usize, u64, usize, u64); 5] = [
        (1, 0, 0, 0),
        (2, 1, 0, 0),
        (4, 0, 0, 0),
        (4, 1, 0, 0),
        (4, 1, 1, 250_000),
    ];
    for (nodes, stripe, replicas, crash_len) in cells {
        let fleet = FleetConfig { mem_nodes: nodes, stripe_pages: stripe, replicas };
        let placement = if nodes == 1 { "single" } else { fleet.placement().name() };
        let tag = if crash_len > 0 { "+crash" } else { "" };
        b.bench(
            format!("pagerank/friendster/{nodes}x-{placement}-r{replicas}{tag}"),
            || {
                let mut wb = Workbench::new(0.0002);
                wb.threads = 24;
                wb.fleet = Some(fleet);
                if crash_len > 0 {
                    wb.fault = Some(FaultConfig {
                        crash_start_ns: 50_000,
                        crash_len_ns: crash_len,
                        crash_every_ns: 1_500_000,
                        seed: 0xF1EE7,
                        ..FaultConfig::default()
                    });
                }
                wb.run(&ExperimentSpec {
                    app: App::PageRank,
                    graph: "friendster",
                    backend: BackendKind::MemServer,
                    caching: CachingMode::None,
                })
                .elapsed_ns
            },
        );
    }
    if let Ok(path) = std::env::var("BENCH_JSON") {
        b.write_json(&path, "fig_fleet").expect("write BENCH_JSON");
        println!("wrote {path}");
    }
}
