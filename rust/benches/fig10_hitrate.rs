//! Bench: Fig 10 — the dynamic cache under real application streams.
use soda::coordinator::config::{BackendKind, CachingMode};
use soda::graph::App;
use soda::util::bench::Bench;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let mut b = Bench::quick();
    b.section("fig10: dynamic-cache hit rates (scale 2e-4)");
    for app in [App::PageRank, App::Bfs] {
        b.bench(format!("{}/friendster/dynamic", app.name()), || {
            let mut wb = Workbench::new(0.0002);
            wb.threads = 24;
            let m = wb.run(&ExperimentSpec {
                app,
                graph: "friendster",
                backend: BackendKind::DPU_FULL,
                caching: CachingMode::Dynamic,
            });
            (m.dpu_hit_rate * 1e6) as u64
        });
    }
}
