//! Bench: Fig 5 — intra vs inter transfers through the full link model.
use soda::fabric::{Fabric, FabricConfig};
use soda::fabric::numa::IntraOp;
use soda::sim::link::TrafficClass;
use soda::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    b.section("fig5: link reservations (the simulator's innermost hot path)");
    b.bench("net_read 64K", || {
        let mut f = Fabric::new(FabricConfig::default());
        let mut t = 0;
        for _ in 0..64 {
            t = f.net_read(t, 64 << 10, 2, TrafficClass::OnDemand);
        }
        black_box(t)
    });
    b.bench("intra DPU->host SEND 64K", || {
        let mut f = Fabric::new(FabricConfig::default());
        let mut t = 0;
        for _ in 0..64 {
            t = f.intra(t, IntraOp::DpuToHostSend, 2, 64 << 10, TrafficClass::OnDemand);
        }
        black_box(t)
    });
    b.bench("figures::fig5()", || soda::figures::fig5().lines.len());
}
