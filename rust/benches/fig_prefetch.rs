//! Bench: the prefetch-policy sweep behind `abl-prefetch` — wall-clock of
//! the simulator runs per engine (off / sequential / strided / graph-hint /
//! adaptive) on the frontier app (BFS) and the streaming app (PageRank).
//! The virtual-time results come from `soda figures abl-prefetch`.

use soda::coordinator::config::{BackendKind, CachingMode, PrefetchOverride};
use soda::dpu::PrefetchPolicyKind;
use soda::graph::App;
use soda::util::bench::Bench;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let mut b = Bench::quick();
    b.section("abl-prefetch: policy x app sweep (scale 2e-4)");
    for app in [App::Bfs, App::PageRank] {
        for policy in PrefetchPolicyKind::ALL {
            b.bench(format!("{}/friendster/{}", app.name(), policy.name()), || {
                let mut wb = Workbench::new(0.0002);
                wb.threads = 24;
                wb.prefetch = Some(PrefetchOverride {
                    policy: Some(policy),
                    ..PrefetchOverride::default()
                });
                wb.run(&ExperimentSpec {
                    app,
                    graph: "friendster",
                    backend: BackendKind::DPU_FULL,
                    caching: CachingMode::Dynamic,
                })
                .elapsed_ns
            });
        }
    }
}
