//! §Perf: the simulator's own hot paths — the targets of the performance
//! pass recorded in EXPERIMENTS.md §Perf. These are *wallclock* benches of
//! the L3 machinery (figures come from virtual time and are unaffected).
use soda::dpu::{CacheTable, EntryKey};
use soda::host::buffer::{PageBuffer, PageKey};
use soda::sim::engine::EventQueue;
use soda::sim::link::{Link, TrafficClass};
use soda::sim::rng::Rng;
use soda::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    b.section("hot paths (per-op cost; §Perf targets)");

    // 1. Page-buffer fault path: access-miss + evict + insert.
    b.bench("buffer miss+evict+insert", || {
        let mut buf = PageBuffer::new(256 * 4096, 4096, 1.0);
        let mut x = 0u64;
        for p in 0..2048u64 {
            if buf.access(PageKey::new(1, p), false).is_none() {
                while buf.is_full() {
                    let ev = buf.evict_lru().unwrap();
                    buf.recycle(ev.data);
                }
                buf.insert_with(PageKey::new(1, p), false, |_| {});
                x += 1;
            }
        }
        black_box(x)
    });

    // 2. Buffer hit path (hash probe only under FaultFifo).
    b.bench("buffer hit (resident)", || {
        let mut buf = PageBuffer::new(256 * 4096, 4096, 1.0);
        for p in 0..256u64 {
            buf.insert_with(PageKey::new(1, p), false, |_| {});
        }
        let mut acc = 0usize;
        for i in 0..4096u64 {
            if buf.access(PageKey::new(1, i % 256), false).is_some() {
                acc += 1;
            }
        }
        black_box(acc)
    });

    // 3. Dynamic cache lookup + insert + random eviction.
    b.bench("cache_table lookup+insert", || {
        let mut t = CacheTable::new(64 * 4096, 4096, 1024);
        let mut rng = Rng::new(3);
        let mut hits = 0usize;
        for e in 0..512u64 {
            if t.lookup_page(0, PageKey::new(1, e * 4)).is_some() {
                hits += 1;
            }
            t.insert(EntryKey { region: 1, entry: e }, vec![0; 4096], 0, &mut rng);
        }
        black_box(hits)
    });

    // 4. Event-queue churn (the thread-merge engine).
    b.bench("event queue push/pop x1024", || {
        let mut q = EventQueue::new();
        let mut rng = Rng::new(9);
        let mut acc = 0u64;
        for i in 0..1024u64 {
            q.push(rng.below(1 << 40) + acc, i);
            if i % 2 == 0 {
                if let Some((t, _)) = q.pop() {
                    acc = t;
                }
            }
        }
        while let Some((t, _)) = q.pop() {
            acc = t;
        }
        black_box(acc)
    });

    // 5. Link reservation (called once per simulated transfer).
    b.bench("link transfer", || {
        let mut l = Link::new("l", 12.5, 2_000, 100);
        let mut t = 0;
        for _ in 0..1024 {
            t = l.transfer(t, 4096, TrafficClass::OnDemand);
        }
        black_box(t)
    });

    // 6. End-to-end simulated fault throughput (the §Perf headline).
    b.section("end-to-end simulated fault path");
    b.bench("memserver fault (full path)", || {
        use soda::backend::MemServerStore;
        use soda::coordinator::cluster::Cluster;
        use soda::coordinator::config::ClusterConfig;
        use soda::host::{HostAgent, Placement};
        let cluster = Cluster::build(ClusterConfig::tiny());
        let chunk = cluster.config().chunk_bytes;
        let mut a = HostAgent::new(
            "b", Box::new(MemServerStore::new(cluster.clone())),
            64 * chunk, chunk, 1.0, 8, 8, 2, soda::host::HostTiming::default(),
        );
        let (h, t0) = a.alloc(0, "x", 512 * chunk, Some(vec![1; (512 * chunk) as usize]), Placement::Default);
        let mut t = t0;
        for p in 0..512u64 {
            t = a.touch_page(t, (p % 8) as usize, PageKey::new(h.region, p), false);
        }
        black_box(t)
    });
}
