//! Bench: the worker-lane sweep behind `abl-scaling` — wall-clock of the
//! simulator runs per (app, host workers) cell on the fault-heavy
//! `dpu-opt` path, buffer shards tracking the lane count. The virtual-time
//! scaling results come from `soda figures abl-scaling`; set
//! `BENCH_JSON=<path>` to also dump these wall-clock stats as a
//! `BENCH_scaling_wallclock.json` trajectory record.

use soda::coordinator::config::{BackendKind, CachingMode};
use soda::graph::App;
use soda::util::bench::Bench;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let mut b = Bench::quick();
    b.section("abl-scaling: host workers x app, dpu-opt (scale 2e-4)");
    for app in [App::Bfs, App::PageRank] {
        for workers in [1usize, 2, 4, 8] {
            b.bench(format!("{}/friendster/w{workers}", app.name()), || {
                let mut wb = Workbench::new(0.0002);
                wb.threads = 24;
                wb.host_workers = Some(workers);
                wb.buffer_shards = Some(workers);
                wb.run(&ExperimentSpec {
                    app,
                    graph: "friendster",
                    backend: BackendKind::DPU_OPT,
                    caching: CachingMode::None,
                })
                .elapsed_ns
            });
        }
    }
    if let Ok(path) = std::env::var("BENCH_JSON") {
        b.write_json(&path, "fig_scaling").expect("write BENCH_JSON");
        println!("wrote {path}");
    }
}
