//! Bench: Fig 9 — traffic accounting under the three caching modes.
use soda::coordinator::config::{BackendKind, CachingMode};
use soda::graph::App;
use soda::util::bench::Bench;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let mut b = Bench::quick();
    b.section("fig9: caching-mode traffic (scale 2e-4)");
    for (label, backend, caching) in [
        ("server-only", BackendKind::MemServer, CachingMode::None),
        ("static", BackendKind::DPU_OPT, CachingMode::Static),
        ("dynamic", BackendKind::DPU_FULL, CachingMode::Dynamic),
    ] {
        b.bench(format!("radii/friendster/{label}"), || {
            let mut wb = Workbench::new(0.0002);
            wb.threads = 24;
            wb.run(&ExperimentSpec {
                app: App::Radii,
                graph: "friendster",
                backend,
                caching,
            })
            .network_bytes()
        });
    }
}
