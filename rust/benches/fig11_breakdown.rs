//! Bench: Fig 11 — per-optimization ablation runs.
use soda::coordinator::config::{BackendKind, CachingMode};
use soda::dpu::DpuOpts;
use soda::graph::App;
use soda::util::bench::Bench;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let mut b = Bench::quick();
    b.section("fig11: optimization ablations (scale 2e-4)");
    let configs: [(&str, BackendKind, CachingMode); 3] = [
        ("base", BackendKind::DPU_BASE, CachingMode::None),
        (
            "aggregation",
            BackendKind::Dpu(DpuOpts { aggregation: true, async_forward: false, dynamic_cache: false }),
            CachingMode::None,
        ),
        ("static", BackendKind::DPU_BASE, CachingMode::Static),
    ];
    for (label, backend, caching) in configs {
        b.bench(format!("bc/friendster/{label}"), || {
            let mut wb = Workbench::new(0.0002);
            wb.threads = 24;
            wb.run(&ExperimentSpec { app: App::Bc, graph: "friendster", backend, caching })
                .elapsed_ns
        });
    }
}
