//! Bench: Fig 3 — NUMA model evaluation cost + the figure's data itself.
use soda::fabric::numa::{IntraOp, NumaModel};
use soda::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    b.section("fig3: NUMA bandwidth model (hot-path cost of the timing model)");
    let m = NumaModel::default();
    b.bench("bandwidth_gbps(64K)", || {
        let mut acc = 0.0;
        for op in IntraOp::ALL {
            for n in 0..4 {
                acc += m.bandwidth_gbps(op, n, 64 << 10);
            }
        }
        black_box(acc)
    });
    b.bench("latency_ns(all ops/nodes)", || {
        let mut acc = 0;
        for op in IntraOp::ALL {
            for n in 0..4 {
                acc += m.latency_ns(op, n);
            }
        }
        black_box(acc)
    });
    b.section("fig3 regeneration (virtual-time figure)");
    b.bench("figures::fig3()", || soda::figures::fig3().lines.len());
}
