//! Bench: Fig 10 extension — dynamic-cache hit rate per replacement policy.
//!
//! Sweeps every engine of the unified cache subsystem over the Fig 10
//! application streams (sequential PageRank vs frontier BFS), reporting the
//! wallclock cost of each policy's bookkeeping alongside the hit rate the
//! virtual-time run produced (`soda figures abl-cache-policy` prints the
//! full hit-rate/traffic table).

use soda::cache::PolicyKind;
use soda::coordinator::config::{BackendKind, CachingMode};
use soda::graph::App;
use soda::util::bench::Bench;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let mut b = Bench::quick();
    b.section("fig10+: dynamic-cache hit rate by replacement policy (scale 2e-4)");
    for app in [App::PageRank, App::Bfs] {
        for policy in PolicyKind::ALL {
            b.bench(format!("{}/friendster/{}", app.name(), policy.name()), || {
                let mut wb = Workbench::new(0.0002);
                wb.threads = 24;
                wb.dpu_cache_policy = Some(policy);
                let m = wb.run(&ExperimentSpec {
                    app,
                    graph: "friendster",
                    backend: BackendKind::DPU_FULL,
                    caching: CachingMode::Dynamic,
                });
                (m.dpu_hit_rate * 1e6) as u64
            });
        }
    }
}
