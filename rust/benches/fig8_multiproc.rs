//! Bench: Fig 8 — co-running foreground app + background BFS.
use soda::coordinator::config::{BackendKind, CachingMode};
use soda::graph::App;
use soda::util::bench::Bench;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let mut b = Bench::quick();
    b.section("fig8: multi-process co-run (scale 2e-4)");
    b.bench("pagerank+bgbfs soda", || {
        let mut wb = Workbench::new(0.0002);
        wb.threads = 24;
        wb.run_with_background_bfs(&ExperimentSpec {
            app: App::PageRank,
            graph: "friendster",
            backend: BackendKind::DPU_OPT,
            caching: CachingMode::Static,
        })
        .0
        .elapsed_ns
    });
}
