//! Bench: Fig 6 — SSD vs MemServer end-to-end app runs (scaled down).
use soda::coordinator::config::{BackendKind, CachingMode};
use soda::graph::App;
use soda::util::bench::Bench;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let mut b = Bench::quick();
    b.section("fig6: end-to-end app on each baseline (scale 2e-4)");
    for backend in [BackendKind::Ssd, BackendKind::MemServer] {
        b.bench(format!("bfs/friendster/{}", backend.label()), || {
            let mut wb = Workbench::new(0.0002);
            wb.threads = 24;
            wb.run(&ExperimentSpec {
                app: App::Bfs,
                graph: "friendster",
                backend,
                caching: CachingMode::None,
            })
            .elapsed_ns
        });
    }
}
