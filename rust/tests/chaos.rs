//! Chaos property suite for the fault-injection engine + reliable fabric
//! layer: under any seeded `FaultPlan` whose faults stay below the retry
//! budget (drops ≤ 5%, finite crash windows), every application must
//! produce **bit-identical** results to a fault-free run — degradation is
//! time and retry traffic, never wrong answers. On top of that:
//!
//! * the fault ledger balances: every injected corruption/dup is detected,
//!   every drop/crash-rejection times out, and every timeout is either
//!   retried or handed to failover as an exhaustion;
//! * retry traffic stays bounded (well under the goodput);
//! * with faults disabled the whole layer is zero-cost: identical virtual
//!   time and network bytes regardless of the configured seed, and an
//!   all-zero `FaultStats`;
//! * the same holds **multi-node**: under any seeded per-node crash plan
//!   with R ≥ 1 replicas, a sharded fleet run stays bit-identical to the
//!   fault-free single-node run, the aggregate failover ledger balances,
//!   and traffic reaches every node;
//! * the same holds under **dynamic membership**: a node permanently
//!   killed mid-run (R ≥ 1), or drained/joined with live shard
//!   migration, never changes an output bit — the coordinator declares
//!   the death, re-replicates from survivors, fences stale epochs, and
//!   the membership ledger balances (rejects == retries, R restored).
//!
//! CI runs this as the "Chaos guard" + "Membership guard" steps.

use soda::backend::{DpuStore, FailoverStore, RemoteStore};
use soda::coordinator::cluster::Cluster;
use soda::coordinator::config::ClusterConfig;
use soda::dpu::DpuOpts;
use soda::fleet::{FleetConfig, FleetNodeStats, FleetStore, MembershipConfig, MembershipStats};
use soda::graph::apps::{bc, bfs, cc, pagerank, radii};
use soda::graph::{gen, BuildMode, CsrGraph, FamGraph, GraphRunner};
use soda::host::{HostAgent, HostTiming};
use soda::sim::fault::{FaultConfig, FaultStats};

/// Small-but-real graph: enough pages that a 24-page buffer keeps the
/// remote path (and its faults) busy through every app.
fn chaos_graph() -> CsrGraph {
    gen::rmat(256, 2048, 0.57, 0.19, 0.19, 7)
}

/// Build a runner over a DPU_FULL cluster carrying `fault`. With faults
/// armed the host uses the failover store (DPU primary, direct-memserver
/// fallback), exactly as `SodaService` selects it; disabled plans keep the
/// plain DPU path so the zero-cost guard compares like with like.
fn runner_with(fault: FaultConfig, csr: &CsrGraph) -> (GraphRunner, FamGraph, Cluster) {
    let mut cfg = ClusterConfig::tiny();
    cfg.dpu.opts = DpuOpts::FULL;
    cfg.fault = fault;
    let cluster = Cluster::build(cfg);
    let chunk = cluster.config().chunk_bytes;
    let store: Box<dyn RemoteStore> = if cluster.config().fault.enabled() {
        Box::new(FailoverStore::new(cluster.clone()))
    } else {
        Box::new(DpuStore::new(cluster.clone()))
    };
    let agent = HostAgent::new(
        "chaos",
        store,
        24 * chunk,
        chunk,
        0.9,
        4,
        4,
        2,
        HostTiming::default(),
    );
    let mut r = GraphRunner::new(agent, 4, 0);
    let (g, t) = FamGraph::build(&mut r.agent, 0, csr, BuildMode::FileBacked);
    r.set_clock(t);
    (r, g, cluster)
}

/// Every fault the plan injects must be accounted for downstream: nothing
/// slips through undetected and nothing is detected out of thin air.
fn assert_ledger_balances(s: &FaultStats, ctx: &str) {
    assert_eq!(
        s.detected_corruptions, s.injected_corruptions,
        "{ctx}: every injected corruption must be caught by the checksum"
    );
    assert_eq!(
        s.detected_dups, s.injected_dups,
        "{ctx}: every injected duplicate completion must be deduplicated"
    );
    assert_eq!(
        s.timeouts,
        s.injected_drops + s.crash_rejections,
        "{ctx}: drops and crash rejections are the only timeout sources"
    );
    assert_eq!(
        s.timeouts + s.detected_corruptions,
        s.retries + s.exhaustions,
        "{ctx}: every failed attempt is either retried or exhausted"
    );
}

struct AppRun {
    digest: String,
    fault: FaultStats,
    net_bytes: u64,
    elapsed_ns: u64,
}

/// Run all five apps, each on a fresh cluster carrying `fault`, and record
/// an output digest (exact bit-patterns via `{:?}`) plus the fault ledger.
fn run_all(fault: FaultConfig, csr: &CsrGraph) -> Vec<AppRun> {
    let mut runs = Vec::new();
    let mut record = |digest: String, cluster: &Cluster, r: &GraphRunner| {
        runs.push(AppRun {
            digest,
            fault: cluster.fault_stats(),
            net_bytes: cluster.network_stats().network_bytes(),
            elapsed_ns: r.now(),
        });
    };
    {
        let (mut r, g, cluster) = runner_with(fault, csr);
        let out = bfs(&mut r, &g, 0);
        record(
            format!("bfs {:?} {:?} {}", out.levels, out.parents, out.rounds),
            &cluster,
            &r,
        );
    }
    {
        let (mut r, g, cluster) = runner_with(fault, csr);
        let out = pagerank(&mut r, &g, 10);
        record(
            format!("pagerank {:?} {}", out.ranks, out.last_delta),
            &cluster,
            &r,
        );
    }
    {
        let (mut r, g, cluster) = runner_with(fault, csr);
        let out = cc(&mut r, &g);
        record(
            format!("cc {:?} {}", out.labels, out.components),
            &cluster,
            &r,
        );
    }
    {
        let (mut r, g, cluster) = runner_with(fault, csr);
        let out = bc(&mut r, &g, 0);
        record(
            format!("bc {:?} {:?} {:?}", out.scores, out.levels, out.sigma),
            &cluster,
            &r,
        );
    }
    {
        let (mut r, g, cluster) = runner_with(fault, csr);
        let out = radii(&mut r, &g, 0xAD11);
        record(
            format!("radii {:?} {:?}", out.radii, out.sources),
            &cluster,
            &r,
        );
    }
    runs
}

/// Build a runner over a fleet-armed cluster: N memory nodes behind the
/// region directory with the `FleetStore` backend, exactly as
/// `SodaService` selects it when `--mem-nodes > 1`. The cluster derives
/// a per-node fault plan from `fault` (distinct RNG seed per node, crash
/// windows staggered by one window length), so a shard's primary and its
/// ring replica are never down at the same instant.
fn fleet_runner_with(
    fault: FaultConfig,
    fleet: FleetConfig,
    membership: MembershipConfig,
    csr: &CsrGraph,
) -> (GraphRunner, FamGraph, Cluster) {
    let mut cfg = ClusterConfig::tiny();
    cfg.fault = fault;
    cfg.fleet = fleet;
    cfg.membership = membership;
    let cluster = Cluster::build(cfg);
    let chunk = cluster.config().chunk_bytes;
    let store: Box<dyn RemoteStore> = Box::new(FleetStore::new(cluster.clone()));
    let agent = HostAgent::new(
        "chaos",
        store,
        24 * chunk,
        chunk,
        0.9,
        4,
        4,
        2,
        HostTiming::default(),
    );
    let mut r = GraphRunner::new(agent, 4, 0);
    let (g, t) = FamGraph::build(&mut r.agent, 0, csr, BuildMode::FileBacked);
    r.set_clock(t);
    (r, g, cluster)
}

/// Fleet twin of [`run_all`]: all five apps, each on a fresh fleet
/// cluster, recording the same digests plus the per-node fleet counters
/// and the membership ledger.
fn run_all_fleet(
    fault: FaultConfig,
    fleet: FleetConfig,
    membership: MembershipConfig,
    csr: &CsrGraph,
) -> Vec<(AppRun, Vec<FleetNodeStats>, MembershipStats)> {
    let mut runs = Vec::new();
    let mut record = |digest: String, cluster: &Cluster, r: &GraphRunner| {
        runs.push((
            AppRun {
                digest,
                fault: cluster.fault_stats(),
                net_bytes: cluster.network_stats().network_bytes(),
                elapsed_ns: r.now(),
            },
            cluster.fleet_node_stats(),
            cluster.membership_stats(),
        ));
    };
    {
        let (mut r, g, cluster) = fleet_runner_with(fault, fleet, membership, csr);
        let out = bfs(&mut r, &g, 0);
        record(
            format!("bfs {:?} {:?} {}", out.levels, out.parents, out.rounds),
            &cluster,
            &r,
        );
    }
    {
        let (mut r, g, cluster) = fleet_runner_with(fault, fleet, membership, csr);
        let out = pagerank(&mut r, &g, 10);
        record(
            format!("pagerank {:?} {}", out.ranks, out.last_delta),
            &cluster,
            &r,
        );
    }
    {
        let (mut r, g, cluster) = fleet_runner_with(fault, fleet, membership, csr);
        let out = cc(&mut r, &g);
        record(
            format!("cc {:?} {}", out.labels, out.components),
            &cluster,
            &r,
        );
    }
    {
        let (mut r, g, cluster) = fleet_runner_with(fault, fleet, membership, csr);
        let out = bc(&mut r, &g, 0);
        record(
            format!("bc {:?} {:?} {:?}", out.scores, out.levels, out.sigma),
            &cluster,
            &r,
        );
    }
    {
        let (mut r, g, cluster) = fleet_runner_with(fault, fleet, membership, csr);
        let out = radii(&mut r, &g, 0xAD11);
        record(
            format!("radii {:?} {:?}", out.radii, out.sources),
            &cluster,
            &r,
        );
    }
    runs
}

/// A plan that exercises every injector at once: drops, corruption, dup
/// completions, latency spikes and periodic memory-node crash windows that
/// outlast the DPU path's retry budget (forcing real failovers).
fn chaos_cfg(seed: u64) -> FaultConfig {
    FaultConfig {
        drop_rate: 0.04,
        corrupt_rate: 0.01,
        dup_rate: 0.01,
        spike_rate: 0.02,
        spike_ns: 40_000,
        crash_start_ns: 50_000,
        crash_len_ns: 250_000,
        crash_every_ns: 1_500_000,
        seed,
    }
}

#[test]
fn chaos_runs_are_bit_identical_to_fault_free() {
    let csr = chaos_graph();
    let clean = run_all(FaultConfig::default(), &csr);
    for s in &clean {
        assert_eq!(s.fault.injected(), 0, "clean run must inject nothing");
    }
    for seed in [1u64, 0xC0FFEE] {
        let chaos = run_all(chaos_cfg(seed), &csr);
        let mut injected = 0;
        let mut failovers = 0;
        for (c, f) in clean.iter().zip(&chaos) {
            let app = f.digest.split(' ').next().unwrap_or("?");
            assert_eq!(
                c.digest, f.digest,
                "seed {seed:#x}: {app} diverged from the fault-free run"
            );
            assert_ledger_balances(&f.fault, &format!("seed {seed:#x} {app}"));
            // Retry traffic stays a small fraction of the goodput.
            assert!(
                f.fault.retry_bytes <= f.net_bytes / 4,
                "seed {seed:#x} {app}: retry bytes {} vs net {}",
                f.fault.retry_bytes,
                f.net_bytes
            );
            // Degradation only ever costs time.
            assert!(
                f.elapsed_ns >= c.elapsed_ns,
                "seed {seed:#x} {app}: chaos run finished faster than clean"
            );
            injected += f.fault.injected();
            failovers += f.fault.failovers;
        }
        assert!(injected > 0, "seed {seed:#x}: the plan never fired");
        assert!(
            failovers > 0,
            "seed {seed:#x}: crash windows beyond the retry budget must trip failover"
        );
    }
}

#[test]
fn disabled_faults_are_zero_cost_whatever_the_seed() {
    let csr = chaos_graph();
    // Same all-zero rates, wildly different seeds: if the disabled plan
    // consulted its RNG anywhere on the data path, these would diverge.
    let a = run_all(FaultConfig::default(), &csr);
    let b = run_all(
        FaultConfig {
            seed: 0xDEAD_BEEF,
            ..FaultConfig::default()
        },
        &csr,
    );
    for (x, y) in a.iter().zip(&b) {
        let app = x.digest.split(' ').next().unwrap_or("?");
        assert_eq!(x.digest, y.digest, "{app}: outputs must match");
        assert_eq!(x.elapsed_ns, y.elapsed_ns, "{app}: timing must match");
        assert_eq!(x.net_bytes, y.net_bytes, "{app}: traffic must match");
        for s in [&x.fault, &y.fault] {
            assert_eq!(s.injected(), 0, "{app}: nothing injected");
            assert_eq!(s.retries + s.exhaustions + s.timeouts, 0, "{app}: no retry activity");
            assert_eq!(s.retry_bytes + s.backoff_ns, 0, "{app}: no retry cost");
            assert_eq!(s.failovers + s.recoveries, 0, "{app}: no breaker activity");
        }
    }
}

#[test]
fn corruption_alone_is_always_caught_and_corrected() {
    let csr = chaos_graph();
    let clean = run_all(FaultConfig::default(), &csr);
    let corrupt = run_all(
        FaultConfig {
            corrupt_rate: 0.03,
            seed: 11,
            ..FaultConfig::default()
        },
        &csr,
    );
    let mut caught = 0;
    for (c, f) in clean.iter().zip(&corrupt) {
        assert_eq!(c.digest, f.digest, "corruption must never reach the app");
        assert_ledger_balances(&f.fault, "corrupt-only");
        caught += f.fault.detected_corruptions;
    }
    assert!(caught > 0, "a 3% corruption rate must fire at least once");
}

#[test]
fn fleet_chaos_stays_bit_identical_to_single_node_fault_free() {
    let csr = chaos_graph();
    // Reference: the fault-free *single-node* DPU run. Sharding the data
    // across a fleet — with or without per-node faults — must never
    // change a single output bit.
    let clean = run_all(FaultConfig::default(), &csr);
    let fleet = FleetConfig {
        mem_nodes: 4,
        stripe_pages: 2,
        replicas: 1,
    };

    // Fault-free fleet: same answers, and striping genuinely spreads the
    // traffic across every node.
    for (c, (f, nodes, _)) in clean
        .iter()
        .zip(&run_all_fleet(FaultConfig::default(), fleet, MembershipConfig::default(), &csr))
    {
        let app = f.digest.split(' ').next().unwrap_or("?");
        assert_eq!(c.digest, f.digest, "fleet (clean): {app} diverged from single-node");
        assert_eq!(f.fault.injected(), 0, "fleet (clean) {app}: nothing injected");
        assert_eq!(nodes.len(), 4, "{app}: one stat row per node");
        for n in nodes {
            assert!(n.net_bytes > 0, "fleet (clean) {app}: node {} idle", n.node);
        }
    }

    // Seeded per-node crash plans (plus the full injector mix) with one
    // replica per range: every app still matches bit-for-bit, the
    // aggregate ledger balances, and crash windows outlasting the retry
    // budget actually move leases.
    let mut recoveries = 0;
    for seed in [3u64, 0xFEE7] {
        let chaos = run_all_fleet(chaos_cfg(seed), fleet, MembershipConfig::default(), &csr);
        let mut injected = 0;
        let mut failovers = 0;
        for (c, (f, nodes, _)) in clean.iter().zip(&chaos) {
            let app = f.digest.split(' ').next().unwrap_or("?");
            assert_eq!(
                c.digest, f.digest,
                "fleet seed {seed:#x}: {app} diverged from the fault-free single-node run"
            );
            assert_ledger_balances(&f.fault, &format!("fleet seed {seed:#x} {app}"));
            for n in nodes {
                assert!(n.net_bytes > 0, "fleet seed {seed:#x} {app}: node {} idle", n.node);
            }
            injected += f.fault.injected();
            failovers += f.fault.failovers;
            recoveries += f.fault.recoveries;
        }
        assert!(injected > 0, "fleet seed {seed:#x}: the plan never fired");
        assert!(
            failovers > 0,
            "fleet seed {seed:#x}: staggered crash windows must move at least one lease"
        );
    }
    assert!(
        recoveries > 0,
        "a re-probe after the crash windows clear must hand some lease back to its primary"
    );
}

/// Tentpole property (a): a node killed *permanently* mid-run at R = 1
/// never changes an output bit. The coordinator's health score declares
/// the death, every holder chain drops the corpse, and anti-entropy
/// repair restores the replication factor on the survivors — all charged
/// on the real links, all epoch-fenced, with a balanced ledger.
#[test]
fn permanent_node_kill_stays_bit_identical_and_restores_replication() {
    let csr = chaos_graph();
    let clean = run_all(FaultConfig::default(), &csr);
    let fleet = FleetConfig {
        mem_nodes: 3,
        stripe_pages: 1,
        replicas: 1,
    };
    let membership = MembershipConfig {
        fail_threshold: 2,
        kill_node: 1,
        kill_at_ns: 400_000,
        ..MembershipConfig::default()
    };
    // Faster probe sweeps (the recovery knobs are non-arming: no fault
    // is injected beyond the scheduled kill itself).
    let fault = FaultConfig {
        reprobe_ns: 150_000,
        ..FaultConfig::default()
    };
    for (c, (f, _nodes, m)) in clean
        .iter()
        .zip(&run_all_fleet(fault, fleet, membership, &csr))
    {
        let app = f.digest.split(' ').next().unwrap_or("?");
        assert_eq!(
            c.digest, f.digest,
            "{app}: permanent kill diverged from the fault-free single-node run"
        );
        assert_ledger_balances(&f.fault, &format!("kill {app}"));
        assert_eq!(m.deaths_declared, 1, "{app}: node 1 declared dead exactly once");
        assert!(m.epoch >= 1, "{app}: the death cutover must bump the epoch");
        assert!(m.repair_bytes > 0, "{app}: repair must copy real bytes");
        assert_eq!(
            m.min_holders, 2,
            "{app}: anti-entropy must restore R=1 on the two survivors"
        );
        assert_eq!(
            m.stale_epoch_rejects, m.stale_epoch_retries,
            "{app}: every fenced request must be transparently retried"
        );
        assert_eq!(m.unavailable_regions, 0, "{app}: R=1 never loses a whole chain");
    }
}

/// Tentpole property (b): planned drain + join with live shard migration
/// (copy window, dual-write, epoch-fenced cutover) keeps PageRank
/// bit-identical, and the drained node serves zero bytes after cutover.
#[test]
fn drain_and_join_keep_pagerank_identical_and_silence_the_drained_node() {
    let csr = chaos_graph();
    let (mut r, g, _c) = runner_with(FaultConfig::default(), &csr);
    let clean = pagerank(&mut r, &g, 10);
    let fleet = FleetConfig {
        mem_nodes: 3,
        stripe_pages: 1,
        replicas: 0,
    };
    let membership = MembershipConfig {
        join_at_ns: 200_000,
        drain_node: 0,
        drain_at_ns: 400_000,
        ..MembershipConfig::default()
    };
    let (mut r, g, cluster) =
        fleet_runner_with(FaultConfig::default(), fleet, membership, &csr);
    let out = pagerank(&mut r, &g, 10);
    assert_eq!(
        format!("{:?} {}", clean.ranks, clean.last_delta),
        format!("{:?} {}", out.ranks, out.last_delta),
        "live migration must never change a PageRank bit"
    );
    let m = cluster.membership_stats();
    assert!(m.pages_migrated > 0, "drain + join must move real shards");
    assert_eq!(
        m.post_cutover_drain_bytes, 0,
        "the drained node must see zero wire bytes after its cutover"
    );
    assert_eq!(m.deaths_declared, 0, "planned events are not failures");
    assert!(m.epoch >= 2, "join and drain cutovers each bump the epoch");
    assert_eq!(
        m.stale_epoch_rejects, m.stale_epoch_retries,
        "every fenced request must be transparently retried"
    );
    assert!(cluster.membership_fatal().is_none());
    assert_ledger_balances(&cluster.fault_stats(), "drain+join");
}

/// A membership config with no scheduled events builds no coordinator:
/// virtual time, traffic, and outputs are bit-identical whatever the
/// threshold knob says, and the ledger stays all-zero.
#[test]
fn static_membership_is_zero_cost_whatever_the_threshold() {
    let csr = chaos_graph();
    let fleet = FleetConfig {
        mem_nodes: 3,
        stripe_pages: 1,
        replicas: 1,
    };
    let a = run_all_fleet(FaultConfig::default(), fleet, MembershipConfig::default(), &csr);
    let b = run_all_fleet(
        FaultConfig::default(),
        fleet,
        MembershipConfig {
            fail_threshold: 9,
            ..MembershipConfig::default()
        },
        &csr,
    );
    for ((x, _, mx), (y, _, my)) in a.iter().zip(&b) {
        let app = x.digest.split(' ').next().unwrap_or("?");
        assert_eq!(x.digest, y.digest, "{app}: outputs must match");
        assert_eq!(x.elapsed_ns, y.elapsed_ns, "{app}: timing must match");
        assert_eq!(x.net_bytes, y.net_bytes, "{app}: traffic must match");
        assert_eq!(*mx, MembershipStats::default(), "{app}: ledger stays zero");
        assert_eq!(*my, MembershipStats::default(), "{app}: ledger stays zero");
    }
}

/// Satellite: the structured errors the CLI prints for membership
/// failures — no panics, no unwraps, readable context.
#[test]
fn membership_errors_print_clean_structured_messages() {
    use soda::backend::FetchError;
    use soda::memnode::MemError;
    let e = MemError::RegionUnavailable { region: 7, node: 2 };
    assert_eq!(
        e.to_string(),
        "region 7 unavailable: shard slot 2 lost its entire holder chain"
    );
    let e = MemError::StaleEpoch { have: 1, want: 3 };
    assert!(e.to_string().contains("stale directory epoch 1"), "got: {e}");
    assert!(e.to_string().contains("refresh and retry"), "got: {e}");
    let e = FetchError::Unavailable(MemError::RegionUnavailable { region: 1, node: 0 });
    assert!(e.to_string().contains("unavailable"), "got: {e}");
    assert_eq!(FetchError::Exhausted.to_string(), "retry budget exhausted");
}
