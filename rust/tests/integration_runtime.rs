//! End-to-end AOT round trip: the python-lowered HLO artifacts load,
//! compile and execute through PJRT from Rust, and the numbers match the
//! pure-Rust oracle (which itself matches the python oracle via pytest).
//!
//! Requires `make artifacts` (skips gracefully if absent so `cargo test`
//! works on a fresh checkout).

use soda::runtime::{cpu_client, pagerank_step_ref, to_ell, Manifest, PagerankEngine};
use soda::sim::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn artifact_executes_and_matches_oracle() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&dir).expect("manifest");
    let spec = manifest.find(1024, 8).expect("default test artifact");
    let client = cpu_client().expect("PJRT CPU client");
    let engine = PagerankEngine::load(&client, &dir, spec).expect("compile artifact");

    // Random ELL instance.
    let (n, k) = (engine.n, engine.k);
    let mut rng = Rng::new(42);
    let ranks: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
    let inv_deg: Vec<f32> = (0..n).map(|_| (rng.f64() as f32) * 0.5).collect();
    let cols: Vec<i32> = (0..n * k)
        .map(|_| {
            if rng.chance(0.3) {
                -1
            } else {
                rng.below(n as u64) as i32
            }
        })
        .collect();
    let spill: Vec<f32> = (0..n).map(|_| (rng.f64() as f32) * 0.01).collect();

    let (got, got_delta) = engine.step(&ranks, &inv_deg, &cols, &spill).expect("step");
    let (want, want_delta) = pagerank_step_ref(&ranks, &inv_deg, &cols, k, &spill, 0.85);
    assert_eq!(got.len(), n);
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-4, "rank {i}: {a} vs {b}");
    }
    assert!(
        (got_delta - want_delta).abs() / want_delta.max(1e-6) < 1e-2,
        "delta {got_delta} vs {want_delta}"
    );
}

#[test]
fn engine_rejects_wrong_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest.find(1024, 8).unwrap();
    let client = cpu_client().unwrap();
    let engine = PagerankEngine::load(&client, &dir, spec).unwrap();
    let bad = engine.step(&[0.0; 10], &[0.0; 10], &[0; 80], &[0.0; 10]);
    assert!(bad.is_err());
}

#[test]
fn multi_iteration_convergence_through_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest.find(1024, 8).unwrap();
    let client = cpu_client().unwrap();
    let engine = PagerankEngine::load(&client, &dir, spec).unwrap();
    let n = engine.n;

    // Ring graph: uniform ranks are the fixed point.
    let neighbors: Vec<Vec<u32>> = (0..n)
        .map(|v| vec![((v + 1) % n) as u32, ((v + n - 1) % n) as u32])
        .collect();
    let (cols, spill_lists) = to_ell(&neighbors, n, engine.k);
    assert!(spill_lists.iter().all(|s| s.is_empty()));
    let inv_deg = vec![0.5f32; n];
    let spill = vec![0.0f32; n];
    // Start from a perturbed distribution.
    let mut ranks = vec![1.0 / n as f32; n];
    ranks[0] += 0.1;
    ranks[1] -= 0.1;
    let mut deltas = Vec::new();
    for _ in 0..60 {
        let (next, delta) = engine.step(&ranks, &inv_deg, &cols, &spill).unwrap();
        ranks = next;
        deltas.push(delta);
    }
    assert!(deltas.last().unwrap() < &1e-3, "deltas: {deltas:?}");
    assert!(deltas[0] > deltas[deltas.len() - 1]);
    let uniform = 1.0 / n as f32;
    assert!(ranks.iter().all(|&r| (r - uniform).abs() < 1e-4));
}
