//! Integration: the figure harness regenerates every table/figure with the
//! paper's qualitative shapes at a reduced scale (the full-scale run is
//! recorded in EXPERIMENTS.md via `soda figures --all`).

use soda::figures;
use soda::util::json::Json;

const S: f64 = 0.0002;
const T: usize = 24;

fn rows(r: &figures::FigureReport) -> Vec<Json> {
    match r.data.get("rows") {
        Some(Json::Arr(v)) => v.clone(),
        _ => panic!("{}: no rows", r.id),
    }
}

#[test]
fn fig3_numa2_dominates() {
    let r = figures::fig3();
    for row in rows(&r) {
        if let Some(Json::Arr(bw)) = row.get("bw") {
            let v: Vec<f64> = bw.iter().map(|x| x.as_f64().unwrap()).collect();
            assert!(v[2] >= v[0] && v[2] >= v[1] && v[2] >= v[3]);
        }
    }
}

#[test]
fn fig5_reproduces_50pct_rule() {
    let r = figures::fig5();
    let h = r.data.get("required_hit_rate").unwrap().as_f64().unwrap();
    assert!((0.4..0.55).contains(&h), "testbed rule: ~50% hit rate needed, got {h}");
}

#[test]
fn fig6_memserver_beats_ssd_in_most_cases() {
    let r = figures::fig6(S, T);
    let speedups: Vec<f64> = rows(&r)
        .iter()
        .map(|row| row.get("speedup").unwrap().as_f64().unwrap())
        .collect();
    let wins = speedups.iter().filter(|&&s| s > 1.0).count();
    assert!(
        wins >= 14,
        "paper: 17/20 cases favor network memory; got {wins}/20 ({speedups:?})"
    );
}

#[test]
fn fig7_dpu_base_is_slower_than_memserver() {
    let r = figures::fig7(S, T);
    for row in rows(&r) {
        let ratio = row.get("base_over_mem").unwrap().as_f64().unwrap();
        assert!(
            ratio > 1.0 && ratio < 1.6,
            "naive DPU proxying should cost a bounded slowdown, got {ratio}"
        );
        let opt = row.get("opt_over_mem").unwrap().as_f64().unwrap();
        assert!(opt <= ratio + 0.02, "optimizations must not make DPU slower than base");
    }
}

#[test]
fn fig9_static_caching_reduces_traffic_dynamic_shifts_to_background() {
    let r = figures::fig9(S, T);
    for row in rows(&r) {
        let d_stat = row.get("static_delta").unwrap().as_f64().unwrap();
        assert!(d_stat <= 0.02, "static caching must not add meaningful traffic: {d_stat}");
        let bg_frac = row.get("dynamic_bg_fraction").unwrap().as_f64().unwrap();
        assert!(
            bg_frac > 0.5,
            "dynamic caching must convert most traffic to background ({bg_frac})"
        );
    }
}

#[test]
fn fig10_pagerank_most_predictable() {
    let r = figures::fig10(S, T);
    let mut pr = 0.0;
    let mut bfs = 1.0;
    for row in rows(&r) {
        let app = row.get("app").unwrap().as_str().unwrap().to_string();
        let h = row.get("friendster").unwrap().as_f64().unwrap();
        if app == "pagerank" {
            pr = h;
        }
        if app == "bfs" {
            bfs = h;
        }
    }
    assert!(pr > bfs, "PageRank ({pr}) must out-hit BFS ({bfs}) as in Fig 10");
}

#[test]
fn all_figures_render_nonempty() {
    for id in ["table1", "table2", "fig3", "fig4", "fig5"] {
        let r = figures::run_figure(id, S, T).unwrap();
        assert!(!r.lines.is_empty(), "{id} produced no lines");
        assert!(r.render().contains(id));
    }
}
