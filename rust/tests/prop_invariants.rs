//! Property-based invariants over the coordinator's core state machines
//! (in-tree quickcheck; see util::quickcheck). Each property runs over
//! hundreds of randomized cases with deterministic seeds.

use soda::dpu::{Aggregator, CacheTable, EntryKey, RecentList};
use soda::host::buffer::{EvictPolicy, PageBuffer, PageKey};
use soda::sim::link::{Link, TrafficClass};
use soda::sim::rng::Rng;
use soda::sim::server::ServerPool;
use soda::util::quickcheck::{forall, vec_of, Config};

#[test]
fn prop_buffer_never_exceeds_capacity_and_preserves_data() {
    forall(
        Config { cases: 200, seed: 0xB0F },
        |r| {
            let cap = 2 + r.index(12);
            let ops = vec_of(r, 200, |r| (r.below(32), r.chance(0.4)));
            (cap, ops)
        },
        |(cap, ops)| {
            let mut buf = PageBuffer::new(*cap as u64 * 64, 64, 1.0);
            let mut shadow = std::collections::HashMap::new();
            for (i, &(page, write)) in ops.iter().enumerate() {
                let key = PageKey::new(1, page);
                if buf.access(key, write).is_none() {
                    while buf.is_full() {
                        let ev = buf.evict_lru().ok_or("evict failed on full buffer")?;
                        // dirty pages must carry the latest written tag
                        if ev.dirty {
                            let want = shadow.get(&ev.key.page).ok_or("dirty page unknown")?;
                            if ev.data[0] != *want {
                                return Err(format!("dirty page {:?} lost data", ev.key));
                            }
                        }
                        buf.recycle(ev.data);
                    }
                    let tag = shadow.get(&page).copied().unwrap_or(0);
                    let tag = if write { (i % 251) as u8 } else { tag };
                    buf.insert_with(key, write, |d| d[0] = tag);
                    if write {
                        shadow.insert(page, tag);
                    }
                } else if write {
                    let tag = (i % 251) as u8;
                    buf.peek(key).unwrap()[0] = tag;
                    shadow.insert(page, tag);
                }
                if buf.resident_pages() > *cap {
                    return Err(format!("over capacity: {} > {cap}", buf.resident_pages()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fifo_eviction_order_is_fault_order() {
    forall(
        Config { cases: 100, seed: 0xF1F0 },
        |r| vec_of(r, 40, |r| r.below(1000)),
        |pages| {
            let mut buf = PageBuffer::with_policy(64 * 4096, 4096, 1.0, EvictPolicy::FaultFifo);
            let mut fault_order = Vec::new();
            for &p in pages {
                let key = PageKey::new(1, p);
                if buf.access(key, false).is_none() && !buf.is_resident(key) {
                    buf.insert_with(key, false, |_| {});
                    fault_order.push(key);
                }
            }
            // Evict everything: must come out in fault order.
            let mut evicted = Vec::new();
            while let Some(ev) = buf.evict_lru() {
                evicted.push(ev.key);
            }
            if evicted != fault_order {
                return Err(format!("FIFO violated: {evicted:?} vs {fault_order:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_link_arrivals_are_fifo_and_causal() {
    forall(
        Config { cases: 200, seed: 0x11F0 },
        |r| vec_of(r, 64, |r| (r.below(1_000_000), 1 + r.below(1 << 20))),
        |xfers| {
            let mut link = Link::new("l", 10.0, 1_000, 50);
            let mut sorted = xfers.clone();
            sorted.sort();
            let mut last_arrival = 0;
            for &(t, bytes) in &sorted {
                let arr = link.transfer(t, bytes, TrafficClass::OnDemand);
                if arr < t + 1_000 {
                    return Err(format!("arrival {arr} before latency floor"));
                }
                if arr < last_arrival {
                    return Err("FIFO link reordered arrivals".to_string());
                }
                last_arrival = arr;
            }
            // Conservation: counted bytes == sum of transfers.
            let total: u64 = sorted.iter().map(|&(_, b)| b).sum();
            if link.stats().total_bytes() != total {
                return Err("byte counter mismatch".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_server_pool_work_conservation() {
    forall(
        Config { cases: 200, seed: 0x5E6E },
        |r| {
            let k = 1 + r.index(8);
            let jobs = vec_of(r, 100, |r| (r.below(10_000), 1 + r.below(5_000)));
            (k, jobs)
        },
        |(k, jobs)| {
            let mut pool = ServerPool::new("p", *k);
            let mut sorted = jobs.clone();
            sorted.sort();
            let mut total = 0;
            for &(t, d) in &sorted {
                let (start, end) = pool.admit(t, d);
                if start < t {
                    return Err("job started before arrival".to_string());
                }
                if end - start != d {
                    return Err("service time distorted".to_string());
                }
                total += d;
            }
            if pool.busy_ns() != total {
                return Err("busy time not conserved".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_table_pinned_entries_survive_any_insert_storm() {
    forall(
        Config { cases: 100, seed: 0xCAFE },
        |r| {
            let pinned = r.below(4) as u64;
            let storm = vec_of(r, 64, |r| r.below(512));
            (pinned, storm)
        },
        |(pinned, storm)| {
            let mut t = CacheTable::new(8 * 1024, 1024, 256);
            let mut rng = Rng::new(1);
            for e in 0..=*pinned {
                t.insert(EntryKey { region: 1, entry: e }, vec![0; 1024], 0, &mut rng);
                t.pin(EntryKey { region: 1, entry: e });
            }
            for &e in storm {
                t.insert(EntryKey { region: 2, entry: e }, vec![0; 1024], 0, &mut rng);
            }
            for e in 0..=*pinned {
                if !t.contains(EntryKey { region: 1, entry: e }) {
                    return Err(format!("pinned entry {e} evicted"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_recent_list_holds_last_k() {
    forall(
        Config { cases: 200, seed: 0x11EC },
        |r| vec_of(r, 300, |r| r.below(1 << 20)),
        |pushes| {
            let mut list = RecentList::new(128);
            for &p in pushes {
                list.push(PageKey::new(1, p));
            }
            let n = pushes.len().min(128);
            let latest = list.latest(n);
            for (i, k) in latest.iter().enumerate() {
                let want = pushes[pushes.len() - 1 - i];
                if k.page != want {
                    return Err(format!("latest[{i}] = {} != {want}", k.page));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_aggregator_factor_bounded_and_monotone_in_load() {
    forall(
        Config { cases: 200, seed: 0xA66 },
        |r| {
            let max_batch = 1 + r.below(32);
            let inflight = vec_of(r, 64, |r| 1_000 + r.below(1_000_000));
            (max_batch, inflight)
        },
        |(max_batch, inflight)| {
            let mut a = Aggregator::new(*max_batch);
            for &c in inflight {
                a.record_completion(c);
            }
            let f = a.batch_factor(0);
            if f < 1 || f > *max_batch {
                return Err(format!("factor {f} out of [1, {max_batch}]"));
            }
            if f != (inflight.len() as u64 + 1).min(*max_batch) {
                return Err("factor must equal min(inflight+1, max_batch) at t=0".to_string());
            }
            Ok(())
        },
    );
}
