//! Cache-subsystem invariants across every pluggable replacement policy:
//! residency map ↔ policy-order consistency, pin safety (`pinned_drops`
//! instead of eviction), dirty pages always surfaced through `EvictedPage`,
//! and bit-identical fault-FIFO eviction order vs an explicit reference
//! model of the seed implementation.

use soda::cache::PolicyKind;
use soda::dpu::{CacheTable, EntryKey};
use soda::host::buffer::{PageBuffer, PageKey};
use soda::sim::rng::Rng;
use std::collections::{HashMap, HashSet, VecDeque};

fn k(p: u64) -> PageKey {
    PageKey::new(1, p)
}

fn ek(e: u64) -> EntryKey {
    EntryKey { region: 1, entry: e }
}

/// Mixed insert/touch/evict storm on the host buffer: after every step the
/// engine's order lists exactly the resident keys, each exactly once.
#[test]
fn buffer_order_stays_consistent_with_residency_under_mixed_ops() {
    for policy in PolicyKind::ALL {
        let mut buf = PageBuffer::with_policy(6 * 4096, 4096, 1.0, policy);
        let mut rng = Rng::new(0xBEEF ^ policy.name().len() as u64);
        for step in 0..400u64 {
            let page = rng.below(24);
            let write = rng.chance(0.3);
            if buf.access(k(page), write).is_none() {
                while buf.is_full() {
                    let ev = buf.evict_victim().expect("full buffer must evict");
                    buf.recycle(ev.data);
                }
                buf.insert_with(k(page), write, |d| d[0] = (step % 251) as u8);
            }
            let order = buf.lru_order();
            assert_eq!(
                order.len(),
                buf.resident_pages(),
                "{policy:?}: order length vs resident count at step {step}"
            );
            let set: HashSet<PageKey> = order.iter().copied().collect();
            assert_eq!(set.len(), order.len(), "{policy:?}: duplicate slot in order");
            for key in &order {
                assert!(buf.is_resident(*key), "{policy:?}: order lists evicted {key:?}");
            }
        }
    }
}

/// Pinned DPU-cache entries survive arbitrary insert storms under every
/// policy; when every slot is pinned the insertion is dropped and counted.
#[test]
fn pinned_entries_never_evicted_pinned_drops_counted() {
    for policy in PolicyKind::ALL {
        let mut t = CacheTable::with_policy(4 * 4096, 4096, 1024, policy);
        let mut rng = Rng::new(0xF1A7);
        for e in 0..4u64 {
            assert!(t.insert(ek(e), vec![e as u8; 4096], 0, &mut rng));
        }
        t.pin(ek(0));
        t.pin(ek(1));
        // Storm: pinned entries must survive; unpinned ones churn.
        for e in 10..40u64 {
            t.insert(ek(e), vec![0; 4096], 0, &mut rng);
            assert!(t.contains(ek(0)), "{policy:?}: pinned ek0 evicted");
            assert!(t.contains(ek(1)), "{policy:?}: pinned ek1 evicted");
        }
        // Pin everything resident: the next insert must be dropped and
        // counted, evicting nothing.
        let resident_before = t.resident_entries();
        for e in 0..64u64 {
            if t.contains(ek(e)) && t.refcount(ek(e)) == 0 {
                t.pin(ek(e));
            }
        }
        let drops_before = t.stats().pinned_drops;
        assert!(!t.insert(ek(99), vec![0; 4096], 0, &mut rng), "{policy:?}");
        assert_eq!(t.stats().pinned_drops, drops_before + 1, "{policy:?}");
        assert_eq!(t.resident_entries(), resident_before, "{policy:?}");
        assert!(!t.contains(ek(99)), "{policy:?}");
    }
}

/// Every dirty page leaves the buffer as a dirty `EvictedPage` carrying its
/// latest bytes — under every policy, through both eviction and drain.
#[test]
fn dirty_pages_always_surface_on_eviction() {
    for policy in PolicyKind::ALL {
        let mut buf = PageBuffer::with_policy(5 * 4096, 4096, 1.0, policy);
        let mut rng = Rng::new(0xD1E7);
        let mut shadow_dirty: HashMap<u64, u8> = HashMap::new();
        for step in 0..300u64 {
            let page = rng.below(20);
            let write = rng.chance(0.5);
            let tag = (step % 251) as u8;
            match buf.access(k(page), write) {
                Some(data) => {
                    if write {
                        data[0] = tag;
                        shadow_dirty.insert(page, tag);
                    }
                }
                None => {
                    while buf.is_full() {
                        let ev = buf.evict_victim().expect("full buffer must evict");
                        let expect = shadow_dirty.remove(&ev.key.page);
                        assert_eq!(
                            ev.dirty,
                            expect.is_some(),
                            "{policy:?}: dirty flag wrong for page {}",
                            ev.key.page
                        );
                        if let Some(want) = expect {
                            assert_eq!(ev.data[0], want, "{policy:?}: dirty data lost");
                        }
                        buf.recycle(ev.data);
                    }
                    buf.insert_with(k(page), write, |d| d[0] = tag);
                    if write {
                        shadow_dirty.insert(page, tag);
                    }
                }
            }
        }
        // Whatever dirty pages remain resident must drain as dirty.
        let drained = buf.drain_dirty();
        for ev in &drained {
            assert!(ev.dirty);
            let want = shadow_dirty
                .remove(&ev.key.page)
                .unwrap_or_else(|| panic!("{policy:?}: drained clean page {:?}", ev.key));
            assert_eq!(ev.data[0], want, "{policy:?}: drained data lost");
        }
        assert!(
            shadow_dirty.is_empty(),
            "{policy:?}: dirty pages vanished without surfacing: {shadow_dirty:?}"
        );
    }
}

/// Reference model of the seed's fault-FIFO buffer: an explicit queue in
/// fault order. The default policy must match it *exactly* — same eviction
/// sequence, same dirty flags, same hit/miss counters — on a pseudorandom
/// workload (the acceptance criterion's bit-identical regression check).
#[test]
fn fault_fifo_matches_seed_reference_model_exactly() {
    const CAP: usize = 8;
    let mut buf = PageBuffer::new(CAP as u64 * 4096, 4096, 1.0);
    assert_eq!(buf.policy(), PolicyKind::FaultFifo, "seed default policy");
    let mut fifo: VecDeque<u64> = VecDeque::new(); // fault order, oldest first
    let mut dirty: HashSet<u64> = HashSet::new();
    let mut rng = Rng::new(0x5EED_F1F0);
    let (mut hits, mut misses) = (0u64, 0u64);
    for _ in 0..2_000 {
        let page = rng.below(32);
        let write = rng.chance(0.25);
        if buf.access(k(page), write).is_some() {
            hits += 1;
            assert!(fifo.contains(&page), "model out of sync");
            if write {
                dirty.insert(page);
            }
            // Seed semantics: a hit must NOT change the fault order.
        } else {
            misses += 1;
            assert!(!fifo.contains(&page), "model out of sync");
            while fifo.len() >= CAP {
                let expect = fifo.pop_front().unwrap();
                let ev = buf.evict_victim().expect("buffer full");
                assert_eq!(ev.key.page, expect, "eviction diverged from fault order");
                assert_eq!(ev.dirty, dirty.remove(&expect), "dirty flag diverged");
                buf.recycle(ev.data);
            }
            buf.insert_with(k(page), write, |_| {});
            fifo.push_back(page);
            if write {
                dirty.insert(page);
            }
        }
    }
    let s = buf.stats();
    assert_eq!((s.hits, s.misses), (hits, misses), "stats diverged");
    // Drain the rest: still exact fault order.
    while let Some(expect) = fifo.pop_front() {
        let ev = buf.evict_victim().expect("resident pages remain");
        assert_eq!(ev.key.page, expect, "tail eviction diverged from fault order");
    }
    assert_eq!(buf.resident_pages(), 0);
}

/// Golden fixed sequence for the default policy (hand-computed seed
/// behavior): hits never reorder, evictions follow first-fault order.
#[test]
fn fault_fifo_golden_sequence() {
    let mut buf = PageBuffer::new(3 * 4096, 4096, 1.0);
    for p in [10u64, 20, 30] {
        buf.insert_with(k(p), false, |_| {});
    }
    buf.access(k(10), false); // hot — invisible to uffd
    buf.access(k(30), true); // dirty
    let mut order: Vec<u64> = Vec::new();
    while let Some(ev) = buf.evict_victim() {
        order.push(ev.key.page);
        buf.recycle(ev.data);
    }
    assert_eq!(order, vec![10, 20, 30], "fault order, untouched by hits");
}

/// The DPU cache's residency map and engine agree for every policy under a
/// prefetch-like storm with racing readiness and invalidations.
#[test]
fn cache_table_residency_consistent_under_storm() {
    for policy in PolicyKind::ALL {
        let mut t = CacheTable::with_policy(8 * 4096, 4096, 1024, policy);
        let mut rng = Rng::new(0x570F);
        for step in 0..300u64 {
            match rng.below(4) {
                0 | 1 => {
                    let key = ek(rng.below(40));
                    let _ = t.insert(key, vec![0; 4096], step * 10, &mut rng);
                }
                2 => {
                    // Lookup a page of a random known entry (may be not-ready).
                    let e = rng.below(40);
                    let _ = t.lookup_page(step * 10, PageKey::new(1, e * 4));
                }
                _ => {
                    let key = ek(rng.below(40));
                    if t.refcount(key) == 0 {
                        t.invalidate(key);
                    }
                }
            }
            assert!(
                t.resident_entries() <= t.slot_count(),
                "{policy:?}: over capacity"
            );
        }
        // clear() empties both map and engine; the table is reusable.
        t.clear();
        assert_eq!(t.resident_entries(), 0, "{policy:?}");
        assert!(t.insert(ek(0), vec![1; 4096], 0, &mut rng), "{policy:?}");
        assert!(t.lookup_page(10, PageKey::new(1, 0)).is_some(), "{policy:?}");
    }
}
