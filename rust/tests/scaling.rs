//! Scaling equivalence suite for the multi-worker host agent and the
//! sharded page buffer: parallelism knobs (`W` fault-service worker lanes,
//! `P` buffer shards) are *latency* knobs, never semantic ones. For any
//! seeded (W, P) pair and any backend, a run must be observably equivalent
//! to the serial W=1/P=1 agent — same application output, same fault
//! count, same bytes on the wire, same final buffer contents including
//! per-page dirty state — while never stalling longer than the serial
//! path. On top:
//!
//! * the stamp-merged sharded buffer reproduces the unsharded eviction
//!   *sequence* exactly for the peekable policies (fault-FIFO/access-LRU)
//!   at any shard count;
//! * every interleaving of a writeback lane and a frame-reuse lane over
//!   the packed atomic `FrameState` word is linearizable against a
//!   sequential model: pins never go negative, a pinned frame is never
//!   evicted, dirtiness is never silently lost, and a stale-generation
//!   writeback (the ABA case) never touches the frame's new occupant.

use soda::backend::{DpuStore, MemServerStore, RemoteStore, SsdStore};
use soda::coordinator::cluster::Cluster;
use soda::coordinator::config::ClusterConfig;
use soda::dpu::DpuOpts;
use soda::graph::{gen, App, BuildMode, CsrGraph, FamGraph, GraphRunner};
use soda::host::{EvictPolicy, FrameState, HostAgent, HostTiming, PageBuffer, PageKey};

/// Small-but-real graph: enough pages that a 24-page buffer keeps the
/// remote path (faults, evictions, dirty writebacks) busy in every app.
fn scaling_graph() -> CsrGraph {
    gen::rmat(256, 2048, 0.57, 0.19, 0.19, 7)
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Backend {
    Mem,
    Dpu,
    Ssd,
}

fn store_for(backend: Backend, cluster: &Cluster) -> Box<dyn RemoteStore> {
    match backend {
        Backend::Mem => Box::new(MemServerStore::new(cluster.clone())),
        Backend::Dpu => Box::new(DpuStore::new(cluster.clone())),
        Backend::Ssd => Box::new(SsdStore::new(cluster.clone())),
    }
}

fn fnv(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in data {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Everything a (W, P) configuration may be observed by.
struct Observed {
    digest: u64,
    faults: u64,
    stall_ns: u64,
    net_bytes: u64,
    on_demand_bytes: u64,
    /// Sorted (key, content digest) of every resident page at the end.
    resident: Vec<(PageKey, u64)>,
    /// Sorted (key, content digest) of the dirty subset.
    dirty: Vec<(PageKey, u64)>,
}

fn observe(backend: Backend, app: App, workers: usize, shards: usize, csr: &CsrGraph) -> Observed {
    let mut cfg = ClusterConfig::tiny();
    if backend == Backend::Dpu {
        cfg.dpu.opts = DpuOpts::OPT;
    }
    let cluster = Cluster::build(cfg);
    let chunk = cluster.config().chunk_bytes;
    let mut agent = HostAgent::new(
        "scaling",
        store_for(backend, &cluster),
        24 * chunk,
        chunk,
        0.9,
        4,
        4,
        2,
        HostTiming::default(),
    );
    // Exactly the service's construction order: both knobs land before any
    // traffic (set_host_workers rebuilds the QP pool, set_buffer_shards
    // repartitions the empty residency table).
    agent.set_buffer_shards(shards);
    agent.set_host_workers(workers);
    let mut r = GraphRunner::new(agent, 4, 0);
    let (g, t) = FamGraph::build(&mut r.agent, 0, csr, BuildMode::FileBacked);
    r.set_clock(t);
    let digest = app.run_digest(&mut r, &g);
    let stats = r.agent.stats();
    let net = cluster.network_stats();
    let buf = r.agent.buffer_mut();
    let mut keys: Vec<PageKey> = buf.lru_order();
    keys.sort();
    keys.dedup();
    let resident = keys
        .iter()
        .map(|&k| (k, fnv(buf.peek(k).expect("tracked key not resident"))))
        .collect();
    let dirty = buf
        .drain_dirty()
        .into_iter()
        .map(|e| (e.key, fnv(&e.data)))
        .collect();
    Observed {
        digest,
        faults: stats.faults,
        stall_ns: stats.stall_ns,
        net_bytes: net.network_bytes(),
        on_demand_bytes: net.on_demand_bytes(),
        resident,
        dirty,
    }
}

#[test]
fn any_worker_and_shard_count_is_observably_equivalent_to_the_serial_agent() {
    let csr = scaling_graph();
    // Seeded LCG draws of (W, P): mismatched, equal and maximal pairs all
    // have to hold, not just the W == P diagonal the figures sweep.
    let mut state: u64 = 0x5EED_CAFE;
    let mut rand = |m: usize| {
        state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        (state >> 33) as usize % m + 1
    };
    let mut pairs = vec![(2usize, 2usize), (8, 8)];
    for _ in 0..2 {
        pairs.push((rand(8), rand(8)));
    }
    for backend in [Backend::Mem, Backend::Dpu, Backend::Ssd] {
        for app in [App::Bfs, App::PageRank, App::Components] {
            let base = observe(backend, app, 1, 1, &csr);
            assert!(base.faults > 0, "{backend:?}/{}: workload never faulted", app.name());
            for &(w, p) in &pairs {
                let run = observe(backend, app, w, p, &csr);
                let ctx = format!("{backend:?}/{} W={w} P={p}", app.name());
                assert_eq!(run.digest, base.digest, "{ctx}: output diverged from serial");
                assert_eq!(run.faults, base.faults, "{ctx}: fault count changed");
                assert_eq!(
                    (run.net_bytes, run.on_demand_bytes),
                    (base.net_bytes, base.on_demand_bytes),
                    "{ctx}: data-plane bytes changed"
                );
                assert_eq!(run.resident, base.resident, "{ctx}: final buffer contents changed");
                assert_eq!(run.dirty, base.dirty, "{ctx}: final dirty state changed");
                assert!(
                    run.stall_ns <= base.stall_ns,
                    "{ctx}: stalled longer than serial ({} vs {})",
                    run.stall_ns,
                    base.stall_ns
                );
            }
        }
    }
}

/// Observables of one standalone-buffer drive.
#[derive(Debug, PartialEq, Eq)]
struct Drive {
    evictions: Vec<(PageKey, bool)>,
    resident: Vec<PageKey>,
    dirty: Vec<PageKey>,
}

/// Drive one deterministic access pattern (reuse + writes + demand
/// evictions) through a standalone buffer and record every observable.
fn drive(policy: EvictPolicy, shards: usize) -> Drive {
    let mut buf = PageBuffer::with_policy(16 * 4096, 4096, 1.0, policy);
    buf.set_shards(shards);
    let mut evictions = Vec::new();
    for i in 0..600u64 {
        let page = (i * 7 + i / 5) % 48;
        let write = i % 3 == 0;
        let key = PageKey::new(1, page);
        if buf.access(key, write).is_none() {
            if buf.is_full() {
                let ev = buf.evict_victim().expect("full buffer must yield a victim");
                evictions.push((ev.key, ev.dirty));
                buf.recycle(ev.data);
            }
            buf.insert_with(key, write, |d| d[..8].copy_from_slice(&page.to_le_bytes()));
        }
    }
    let mut resident = buf.lru_order();
    resident.sort();
    resident.dedup();
    let dirty = buf.drain_dirty().into_iter().map(|e| e.key).collect();
    Drive { evictions, resident, dirty }
}

#[test]
fn sharded_buffer_reproduces_the_unsharded_eviction_sequence() {
    // The stamp merge makes per-shard peeks reconstruct the global policy
    // order, so for the peekable policies the full eviction *sequence* —
    // not just the final set — is shard-count invariant.
    for policy in [EvictPolicy::FaultFifo, EvictPolicy::AccessLru] {
        let baseline = drive(policy, 1);
        for p in [2usize, 4, 8] {
            let run = drive(policy, p);
            assert_eq!(
                run.evictions, baseline.evictions,
                "{policy:?} P={p}: eviction sequence diverged from P=1"
            );
            assert_eq!(run.resident, baseline.resident, "{policy:?} P={p}: resident set diverged");
            assert_eq!(run.dirty, baseline.dirty, "{policy:?} P={p}: dirty set diverged");
        }
    }
}

/// One lane's step against the shared frame word.
#[derive(Clone, Copy, Debug)]
enum Op {
    Pin,
    Unpin,
    SetDirty,
    /// Writeback start: snapshot the residency generation.
    CaptureGen,
    /// Writeback completion: generation-checked dirty clear.
    ClearDirtyCaptured,
    /// Evict-and-reuse, gated on evictability (the shell never picks a
    /// pinned victim); on success bumps the generation.
    TryEvictReinsert { dirty: bool },
}

fn interleavings(a: &[Op], b: &[Op]) -> Vec<Vec<Op>> {
    fn go(a: &[Op], b: &[Op], cur: &mut Vec<Op>, out: &mut Vec<Vec<Op>>) {
        if a.is_empty() && b.is_empty() {
            out.push(cur.clone());
            return;
        }
        if let Some((&h, rest)) = a.split_first() {
            cur.push(h);
            go(rest, b, cur, out);
            cur.pop();
        }
        if let Some((&h, rest)) = b.split_first() {
            cur.push(h);
            go(a, rest, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    go(a, b, &mut Vec::new(), &mut out);
    out
}

#[test]
fn every_interleaving_of_writeback_and_reuse_lanes_is_linearizable() {
    // Lane A is the writeback path (snapshot generation → touch the page →
    // complete with a generation-checked clear); lane B is a competing
    // reader plus the evict-and-reuse path. Enumerating all C(8,4) = 70
    // merges of the two programs and replaying each against a sequential
    // model pins down the exact CAS semantics: no interleaving may lose a
    // pin, evict under a pin, drop dirtiness, or let a stale writeback
    // clear the reused frame (ABA).
    let lane_a = [Op::CaptureGen, Op::Pin, Op::Unpin, Op::ClearDirtyCaptured];
    for reuse_dirty in [true, false] {
        let lane_b = [
            Op::Pin,
            Op::SetDirty,
            Op::Unpin,
            Op::TryEvictReinsert { dirty: reuse_dirty },
        ];
        for seq in interleavings(&lane_a, &lane_b) {
            let s = FrameState::new(true);
            // The sequential model.
            let (mut pins, mut dirty, mut generation) = (0u16, true, 1u64);
            let mut captured = None;
            for op in &seq {
                match *op {
                    Op::Pin => {
                        assert_eq!(s.pin(), Ok(pins + 1), "{seq:?}");
                        pins += 1;
                    }
                    Op::Unpin => {
                        assert_eq!(s.unpin(), pins - 1, "{seq:?}");
                        pins -= 1;
                    }
                    Op::SetDirty => {
                        s.set_dirty();
                        dirty = true;
                    }
                    Op::CaptureGen => captured = Some(s.generation()),
                    Op::ClearDirtyCaptured => {
                        let snap = captured.expect("capture precedes clear in program order");
                        let cleared = s.clear_dirty_if_generation(snap);
                        if generation == snap {
                            assert!(cleared, "{seq:?}: live-generation clear refused");
                            dirty = false;
                        } else {
                            assert!(!cleared, "{seq:?}: stale writeback touched a reused frame");
                        }
                    }
                    Op::TryEvictReinsert { dirty: d } => {
                        if s.is_evictable() {
                            assert_eq!(pins, 0, "{seq:?}: evictable while pinned");
                            s.reinsert(d);
                            generation += 1;
                            dirty = d;
                        } else {
                            assert!(pins > 0, "{seq:?}: unpinned frame reported unevictable");
                        }
                    }
                }
                assert_eq!(s.pins(), pins, "{seq:?}");
                assert_eq!(s.is_dirty(), dirty, "{seq:?}");
                assert_eq!(s.generation(), generation, "{seq:?}");
                assert_eq!(s.is_evictable(), pins == 0, "{seq:?}");
            }
        }
    }
}
