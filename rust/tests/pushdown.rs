//! Operator-pushdown equivalence suite: shipping a dense superstep to the
//! DPU as a kernel descriptor is a *traffic* optimization, never a
//! semantic one. For every app, backend and graph seed, a pushdown run
//! must produce the same output digest as the paging path; on backends
//! without near-data compute the `on`/`auto` modes must be *observably*
//! identical to `off` (same faults, same bytes, same final buffer state),
//! because `supports_pushdown` short-circuits before any descriptor is
//! built. On the DPU backend the apps with kernel-expressible dense
//! supersteps (PageRank / BFS / CC) must move strictly fewer total wire
//! bytes, and every configuration must be run-to-run deterministic. A
//! malformed descriptor is declined by the DPU and counted as a host
//! fallback — it can slow a run down but never corrupt it.

use soda::backend::{DpuStore, MemServerStore, RemoteStore, SsdStore};
use soda::coordinator::cluster::Cluster;
use soda::coordinator::config::ClusterConfig;
use soda::dpu::DpuOpts;
use soda::fabric::protocol::{PushdownOp, PushdownRequest, PushdownTarget};
use soda::graph::{gen, App, BuildMode, CsrGraph, FamGraph, GraphRunner};
use soda::host::{HostAgent, HostTiming, PageKey, PushdownMode};

/// Small-but-real graph whose edge data (~64 KB symmetrized) exceeds the
/// 8-page host buffer below, so the paging path re-faults adjacency pages
/// on every dense superstep — the disaggregated-memory premise (working
/// set >> local buffer) that pushdown's byte win rests on. Dense middle
/// supersteps occur in BFS/CC, and CC's first superstep is always dense.
fn pushdown_graph(seed: u64) -> CsrGraph {
    gen::rmat(512, 8192, 0.57, 0.19, 0.19, seed)
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Backend {
    Mem,
    Dpu,
    Ssd,
}

fn store_for(backend: Backend, cluster: &Cluster) -> Box<dyn RemoteStore> {
    match backend {
        Backend::Mem => Box::new(MemServerStore::new(cluster.clone())),
        Backend::Dpu => Box::new(DpuStore::new(cluster.clone())),
        Backend::Ssd => Box::new(SsdStore::new(cluster.clone())),
    }
}

fn fnv(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in data {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Everything a (backend, app, mode) configuration may be observed by.
#[derive(Debug, PartialEq, Eq)]
struct Observed {
    digest: u64,
    faults: u64,
    pushdowns: u64,
    pushdown_fallbacks: u64,
    dpu_pushdowns: u64,
    dpu_declined: u64,
    net_bytes: u64,
    total_wire_bytes: u64,
    pushdown_bytes: u64,
    /// Sorted (key, content digest) of every resident page at the end.
    resident: Vec<(PageKey, u64)>,
    /// Sorted (key, content digest) of the dirty subset.
    dirty: Vec<(PageKey, u64)>,
}

fn observe(backend: Backend, app: App, mode: PushdownMode, csr: &CsrGraph) -> Observed {
    let mut cfg = ClusterConfig::tiny();
    if backend == Backend::Dpu {
        cfg.dpu.opts = DpuOpts::OPT;
    }
    let cluster = Cluster::build(cfg);
    let chunk = cluster.config().chunk_bytes;
    let mut agent = HostAgent::new(
        "pushdown",
        store_for(backend, &cluster),
        8 * chunk,
        chunk,
        0.9,
        4,
        4,
        2,
        HostTiming::default(),
    );
    agent.set_pushdown(mode);
    let mut r = GraphRunner::new(agent, 4, 0);
    let (g, t) = FamGraph::build(&mut r.agent, 0, csr, BuildMode::FileBacked);
    r.set_clock(t);
    let digest = app.run_digest(&mut r, &g);
    let stats = r.agent.stats();
    let net = cluster.network_stats();
    let dpu = cluster.dpu_stats();
    let buf = r.agent.buffer_mut();
    let mut keys: Vec<PageKey> = buf.lru_order();
    keys.sort();
    keys.dedup();
    let resident = keys
        .iter()
        .map(|&k| (k, fnv(buf.peek(k).expect("tracked key not resident"))))
        .collect();
    let dirty = buf
        .drain_dirty()
        .into_iter()
        .map(|e| (e.key, fnv(&e.data)))
        .collect();
    Observed {
        digest,
        faults: stats.faults,
        pushdowns: stats.pushdowns,
        pushdown_fallbacks: stats.pushdown_fallbacks,
        dpu_pushdowns: dpu.pushdowns,
        dpu_declined: dpu.pushdowns_declined,
        net_bytes: net.network_bytes(),
        total_wire_bytes: net.total_wire_bytes(),
        pushdown_bytes: net.pushdown_bytes() + net.pcie_pushdown_bytes(),
        resident,
        dirty,
    }
}

#[test]
fn pushdown_is_digest_invariant_across_apps_backends_and_seeds() {
    for seed in [7u64, 21] {
        let csr = pushdown_graph(seed);
        for backend in [Backend::Mem, Backend::Dpu, Backend::Ssd] {
            for app in App::ALL {
                let base = observe(backend, app, PushdownMode::Off, &csr);
                assert!(
                    base.faults > 0,
                    "{backend:?}/{}/s{seed}: workload never faulted",
                    app.name()
                );
                assert_eq!(base.pushdowns, 0, "off must never ship a kernel");
                assert_eq!(base.pushdown_bytes, 0, "off must move no pushdown bytes");
                for mode in [PushdownMode::On, PushdownMode::Auto] {
                    let run = observe(backend, app, mode, &csr);
                    let ctx = format!("{backend:?}/{}/s{seed}/{}", app.name(), mode.name());
                    // The standing invariant: the output never changes.
                    assert_eq!(run.digest, base.digest, "{ctx}: output diverged from paging");
                    if backend != Backend::Dpu {
                        // No near-data compute: supports_pushdown is false,
                        // so on/auto must be *observably* identical to off —
                        // the whole-app fallback path.
                        assert_eq!(run, base, "{ctx}: fallback path diverged from off");
                    }
                }
            }
        }
    }
}

#[test]
fn pushdown_moves_strictly_fewer_wire_bytes_on_dense_apps() {
    let csr = pushdown_graph(7);
    for app in [App::PageRank, App::Bfs, App::Components] {
        let off = observe(Backend::Dpu, app, PushdownMode::Off, &csr);
        let on = observe(Backend::Dpu, app, PushdownMode::On, &csr);
        let name = app.name();
        assert_eq!(on.digest, off.digest, "{name}: pushdown changed the output");
        assert!(on.pushdowns > 0, "{name}: no dense superstep ever pushed down");
        assert_eq!(on.pushdowns, on.dpu_pushdowns, "{name}: host/DPU kernel ledgers disagree");
        assert_eq!(on.dpu_declined, 0, "{name}: well-formed descriptors were declined");
        assert!(
            on.total_wire_bytes < off.total_wire_bytes,
            "{name}: pushdown must move strictly fewer bytes ({} vs {})",
            on.total_wire_bytes,
            off.total_wire_bytes
        );
        // With a cold buffer the residency probe predicts a win, so auto
        // takes the pushdown path too and never exceeds the paging bytes.
        let auto = observe(Backend::Dpu, app, PushdownMode::Auto, &csr);
        assert_eq!(auto.digest, off.digest, "{name}: auto changed the output");
        assert!(auto.pushdowns > 0, "{name}: auto never pushed down");
        assert!(
            auto.total_wire_bytes <= off.total_wire_bytes,
            "{name}: auto exceeded the paging bytes"
        );
    }
}

#[test]
fn every_pushdown_configuration_is_run_to_run_deterministic() {
    let csr = pushdown_graph(7);
    for app in [App::PageRank, App::Bfs, App::Components] {
        for mode in [PushdownMode::Off, PushdownMode::On, PushdownMode::Auto] {
            let a = observe(Backend::Dpu, app, mode, &csr);
            let b = observe(Backend::Dpu, app, mode, &csr);
            assert_eq!(a, b, "{}/{}: run-to-run nondeterminism", app.name(), mode.name());
        }
    }
}

#[test]
fn malformed_descriptors_are_declined_and_counted_as_fallbacks() {
    let csr = pushdown_graph(7);
    let mut cfg = ClusterConfig::tiny();
    cfg.dpu.opts = DpuOpts::OPT;
    let cluster = Cluster::build(cfg);
    let chunk = cluster.config().chunk_bytes;
    let mut agent = HostAgent::new(
        "decline",
        Box::new(DpuStore::new(cluster.clone())),
        24 * chunk,
        chunk,
        0.9,
        4,
        4,
        2,
        HostTiming::default(),
    );
    agent.set_pushdown(PushdownMode::On);
    let (g, t) = FamGraph::build(&mut agent, 0, &csr, BuildMode::FileBacked);
    let n = csr.n() as u32;
    // MinLabel targets must arrive in strictly ascending vertex order —
    // these don't, so the kernel refuses and the DPU declines the request.
    let (s1, e1) = g.host_offset_pair(1);
    let (s0, e0) = g.host_offset_pair(0);
    let bad = PushdownRequest {
        region_id: g.edges.region,
        op: PushdownOp::MinLabel,
        flags: 0,
        targets: vec![
            PushdownTarget { v: 1, edge_start: s1, edge_count: (e1 - s1) as u32 },
            PushdownTarget { v: 0, edge_start: s0, edge_count: (e0 - s0) as u32 },
        ],
        operand: vec![0u8; n as usize * 4],
    };
    assert!(agent.pushdown(t, &bad).is_none(), "unsorted MinLabel targets must decline");
    // Wrong operand size for SumF64: one byte short of a whole f64 array,
    // so it can't be a valid contribution table for any vertex count.
    let short = PushdownRequest {
        region_id: g.edges.region,
        op: PushdownOp::SumF64,
        flags: 0,
        targets: vec![PushdownTarget { v: 0, edge_start: s0, edge_count: (e0 - s0) as u32 }],
        operand: vec![0u8; 7],
    };
    assert!(agent.pushdown(t, &short).is_none(), "short SumF64 operand must decline");
    let stats = agent.stats();
    assert_eq!(stats.pushdowns, 0, "declined kernels must not count as pushdowns");
    assert_eq!(stats.pushdown_fallbacks, 2, "every decline is a counted fallback");
    let dpu = cluster.dpu_stats();
    assert_eq!(dpu.pushdowns_declined, 2, "the DPU ledger records both declines");
    assert_eq!(dpu.pushdowns, 0);
}
