//! Property test for the batched fault engine: `touch_pages` (and the span
//! reads/writes built on it) must be *observably equivalent* to the
//! sequential per-page loop — identical output bytes, final buffer state,
//! fault/fetch counts and data-plane bytes-on-wire — across random spans,
//! batch sizes, hit/miss/zero-fill mixes and backends. Only completion
//! times may improve.
//!
//! Dynamic DPU caching is deliberately excluded: its prefetcher races
//! in-flight entries against request *times*, so a latency optimization
//! legitimately shifts which later accesses hit — that is the one
//! timing-dependent behavior the equivalence contract does not cover.

use soda::backend::{DpuStore, MemServerStore, RemoteStore, SsdStore};
use soda::coordinator::cluster::Cluster;
use soda::coordinator::config::ClusterConfig;
use soda::dpu::DpuOpts;
use soda::host::{HostAgent, HostTiming, Placement};
use soda::sim::rng::Rng;
use soda::util::quickcheck::{forall, Config};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Backend {
    MemServer,
    Ssd,
    DpuBase,
    DpuOpt,
}

const BACKENDS: [Backend; 4] = [Backend::MemServer, Backend::Ssd, Backend::DpuBase, Backend::DpuOpt];

/// One random workload: spans of reads/writes against a file-backed and an
/// anonymous region, replayed on a sequential and a batched agent.
#[derive(Clone, Debug)]
struct Case {
    buffer_pages: u64,
    batch: u64,
    coalesce: bool,
    /// (use_anon_region, write, page_offset, byte_len)
    ops: Vec<(bool, bool, u64, usize)>,
}

const REGION_PAGES: u64 = 12;

fn gen_case(r: &mut Rng) -> Case {
    let ops = (0..4 + r.index(8))
        .map(|_| {
            let anon = r.chance(0.4);
            let write = r.chance(0.4);
            let start = r.below(REGION_PAGES - 1);
            // Byte length in pages-worth of the tiny config's 4 KB chunks;
            // run_case clamps to the region end.
            let len = 1 + r.index(((REGION_PAGES - start) * 4096) as usize);
            (anon, write, start, len)
        })
        .collect();
    Case {
        buffer_pages: 3 + r.below(10),
        batch: 2 + r.below(31),
        coalesce: r.chance(0.5),
        ops,
    }
}

fn make_agent(backend: Backend, buffer_pages: u64) -> (HostAgent, Cluster) {
    let mut cfg = ClusterConfig::tiny();
    if let Backend::DpuBase = backend {
        cfg.dpu.opts = DpuOpts::BASE;
    }
    if let Backend::DpuOpt = backend {
        cfg.dpu.opts = DpuOpts::OPT;
    }
    let cluster = Cluster::build(cfg);
    let chunk = cluster.config().chunk_bytes;
    let store: Box<dyn RemoteStore> = match backend {
        Backend::MemServer => Box::new(MemServerStore::new(cluster.clone())),
        Backend::Ssd => Box::new(SsdStore::new(cluster.clone())),
        Backend::DpuBase | Backend::DpuOpt => Box::new(DpuStore::new(cluster.clone())),
    };
    let agent = HostAgent::new(
        "prop",
        store,
        buffer_pages * chunk,
        chunk,
        0.9,
        4,
        4,
        2,
        HostTiming::default(),
    );
    (agent, cluster)
}

/// Data-plane bytes the paper's counters would see (network + PCIe data,
/// control-plane excluded — batching coalesces descriptors by design).
fn data_bytes(c: &Cluster) -> u64 {
    let s = c.network_stats();
    s.network_bytes() + s.pcie_bytes()
}

fn run_case(case: &Case, backend: Backend) -> Result<(), String> {
    let (mut seq, c_seq) = make_agent(backend, case.buffer_pages);
    let (mut bat, c_bat) = make_agent(backend, case.buffer_pages);
    seq.set_fetch_batch(1, false);
    bat.set_fetch_batch(case.batch, case.coalesce);
    let chunk = seq.chunk_bytes();
    let bytes = REGION_PAGES * chunk;
    let file: Vec<u8> = (0..bytes).map(|i| (i % 249) as u8).collect();
    let (f1, s0) = seq.alloc(0, "file", bytes, Some(file.clone()), Placement::Default);
    let (a1, s1) = seq.alloc(s0, "anon", bytes, None, Placement::Default);
    let (f2, b0) = bat.alloc(0, "file", bytes, Some(file), Placement::Default);
    let (a2, b1) = bat.alloc(b0, "anon", bytes, None, Placement::Default);
    c_seq.reset_stats();
    c_bat.reset_stats();

    let (mut u, mut v) = (s1, b1);
    for (i, &(anon, write, start_page, len)) in case.ops.iter().enumerate() {
        let off = start_page * chunk;
        let len = len.min((bytes - off) as usize).max(1);
        let (r_seq, r_bat) = if anon { (a1.region, a2.region) } else { (f1.region, f2.region) };
        if write {
            let data: Vec<u8> = (0..len).map(|j| ((i * 31 + j) % 251) as u8).collect();
            u = seq.write_bytes(u, 0, r_seq, off, &data);
            v = bat.write_bytes(v, 0, r_bat, off, &data);
        } else {
            let mut o1 = vec![0u8; len];
            let mut o2 = vec![0u8; len];
            u = seq.read_bytes(u, 0, r_seq, off, &mut o1);
            v = bat.read_bytes(v, 0, r_bat, off, &mut o2);
            if o1 != o2 {
                return Err(format!("op {i}: read bytes diverge"));
            }
        }
    }

    // Counter equivalence: the batched engine replays the sequential
    // buffer-op order, so every observable counter must match exactly.
    let (hs, hb) = (seq.stats(), bat.stats());
    if hs.faults != hb.faults {
        return Err(format!("faults {} vs {}", hs.faults, hb.faults));
    }
    if hs.zero_fills != hb.zero_fills {
        return Err(format!("zero_fills {} vs {}", hs.zero_fills, hb.zero_fills));
    }
    if hs.writebacks != hb.writebacks {
        return Err(format!("writebacks {} vs {}", hs.writebacks, hb.writebacks));
    }
    if hs.sources != hb.sources {
        return Err(format!("fetch sources {:?} vs {:?}", hs.sources, hb.sources));
    }
    let (bs, bb) = (seq.buffer_stats(), bat.buffer_stats());
    if (bs.hits, bs.misses) != (bb.hits, bb.misses) {
        return Err(format!(
            "buffer hits/misses ({}, {}) vs ({}, {})",
            bs.hits, bs.misses, bb.hits, bb.misses
        ));
    }
    // Final residency (and its engine order) must be identical.
    if seq.buffer_stats().evictions_dirty != bat.buffer_stats().evictions_dirty {
        return Err("dirty eviction counts diverge".into());
    }
    if data_bytes(&c_seq) != data_bytes(&c_bat) {
        return Err(format!(
            "bytes-on-wire {} vs {} (batching must not alter traffic)",
            data_bytes(&c_seq),
            data_bytes(&c_bat)
        ));
    }
    // Only completion times may change, and only for the better.
    if v - b1 > u - s1 {
        return Err(format!("batched slower: {} vs {}", v - b1, u - s1));
    }
    // Full content read-back (covers dirty pages still in the buffer).
    let (mut w1, mut w2) = (vec![0u8; bytes as usize], vec![0u8; bytes as usize]);
    for (r_seq, r_bat) in [(f1.region, f2.region), (a1.region, a2.region)] {
        u = seq.read_bytes(u, 0, r_seq, 0, &mut w1);
        v = bat.read_bytes(v, 0, r_bat, 0, &mut w2);
        if w1 != w2 {
            return Err("final region contents diverge".into());
        }
    }
    Ok(())
}

#[test]
fn touch_pages_is_observably_equivalent_to_the_per_page_loop() {
    forall(
        Config { cases: 40, seed: 0xBA7C4 },
        gen_case,
        |case| {
            for backend in BACKENDS {
                run_case(case, backend).map_err(|e| format!("{backend:?}: {e}"))?;
            }
            Ok(())
        },
    );
}

/// Deterministic spot-check on the worst alignment: a span larger than the
/// whole buffer forces the window to evict its own freshly fetched pages
/// mid-walk (the fallback single-fetch path), and equivalence must hold.
#[test]
fn window_larger_than_buffer_stays_equivalent() {
    let case = Case {
        buffer_pages: 3,
        batch: 32,
        coalesce: true,
        ops: vec![
            (false, false, 0, (REGION_PAGES * 4096) as usize),
            (true, true, 2, 6 * 4096),
            (false, false, 1, 9 * 4096),
        ],
    };
    for backend in BACKENDS {
        run_case(&case, backend).unwrap_or_else(|e| panic!("{backend:?}: {e}"));
    }
}
