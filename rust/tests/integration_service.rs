//! Integration: SODA service semantics — multi-process sharing, the
//! analytical model against measured behaviour, and protocol accounting.

use soda::analytic::{Advice, CachingAdvisor};
use soda::coordinator::cluster::Cluster;
use soda::coordinator::config::{BackendKind, CachingMode, ClusterConfig, SodaConfig};
use soda::coordinator::service::SodaService;
use soda::host::Placement;
use soda::workload::{ExperimentSpec, Workbench};

#[test]
fn multiprocess_share_one_dpu_cache() {
    let mut cfg = ClusterConfig::tiny();
    cfg.dpu.opts = soda::dpu::DpuOpts::FULL;
    let cluster = Cluster::build(cfg);
    let svc = SodaService::attach(
        &cluster,
        SodaConfig::default().with_backend(BackendKind::DPU_FULL),
    );
    let chunk = cluster.config().chunk_bytes;
    let mut p0 = svc.client_with_buffer("p0", 8 * chunk);
    let mut p1 = svc.client_with_buffer("p1", 8 * chunk);
    let bytes = 64 * chunk;
    let (h, t0) = p0.alloc(0, "data", bytes, Some(vec![9; bytes as usize]), Placement::Default);
    p1.map_shared("data", h);
    // p0 scans the object sequentially, warming the shared dynamic cache.
    let mut buf = vec![0u8; chunk as usize];
    let mut t = t0;
    for p in 0..64u64 {
        t = p0.read_bytes(t + 50_000, 0, h.region, p * chunk, &mut buf);
    }
    let hits_before_p1 = cluster.dpu_stats().dynamic_hits;
    // p1 reads the same data much later: the shared cache serves it.
    let mut t1 = t + 100_000_000;
    for p in 0..64u64 {
        t1 = p1.read_bytes(t1 + 50_000, 0, h.region, p * chunk, &mut buf);
        assert!(buf.iter().all(|&b| b == 9));
    }
    assert!(
        cluster.dpu_stats().dynamic_hits > hits_before_p1,
        "second process must hit entries cached by the first"
    );
}

#[test]
fn fig8_style_corun_reduces_traffic_with_static_caching() {
    let mut wb = Workbench::new(0.0002);
    wb.threads = 8;
    let spec_mem = ExperimentSpec {
        app: soda::graph::App::PageRank,
        graph: "friendster",
        backend: BackendKind::MemServer,
        caching: CachingMode::None,
    };
    let spec_soda = ExperimentSpec {
        backend: BackendKind::DPU_OPT,
        caching: CachingMode::Static,
        ..spec_mem.clone()
    };
    let (mem, _) = wb.run_with_background_bfs(&spec_mem);
    let (soda_m, _) = wb.run_with_background_bfs(&spec_soda);
    assert!(
        soda_m.network_bytes() < mem.network_bytes(),
        "SODA must reduce multi-process traffic ({} vs {})",
        soda_m.network_bytes(),
        mem.network_bytes()
    );
}

#[test]
fn analytical_model_agrees_with_measured_crossover() {
    // Eq. 3 says dynamic caching helps iff h > B_net/B_intra. Verify the
    // advisor's threshold is consistent with the simulated fabric: serving
    // a chunk at exactly h* from cache vs memnode takes about equal time.
    let cfg = soda::fabric::FabricConfig::default();
    let adv = CachingAdvisor::from_fabric(&cfg);
    let h_star = adv.threshold();
    assert_eq!(adv.advise(h_star + 0.05), Advice::EnableDynamic);
    assert_eq!(adv.advise(h_star - 0.05), Advice::DisableDynamic);
    // Model time at h* ≈ baseline time (Eq. 1 vs Eq. 2), within 1%.
    let s = 64 << 10;
    let t_base = soda::analytic::fetch_time_baseline(s, adv.b_net_gbps);
    let t_dyn = soda::analytic::fetch_time_dynamic(s, adv.b_net_gbps, adv.b_intra_gbps, h_star);
    assert!((t_base - t_dyn).abs() / t_base < 1e-9);
}

#[test]
fn traffic_counters_are_conserved() {
    // Bytes leaving the memory node = bytes arriving at the compute node:
    // one link, so data_bytes on rx counts both. Check on-demand+bg+wb
    // decomposition sums to the total.
    let mut wb = Workbench::new(0.0002);
    wb.threads = 8;
    let m = wb.run(&ExperimentSpec {
        app: soda::graph::App::Components,
        graph: "twitter7",
        backend: BackendKind::DPU_FULL,
        caching: CachingMode::Dynamic,
    });
    let total = m.network.network_bytes();
    let parts = m.network.on_demand_bytes() + m.network.background_bytes() + m.network.writeback_bytes();
    assert_eq!(total, parts, "traffic classes must partition the total");
    assert!(m.network.background_fraction() > 0.0 && m.network.background_fraction() < 1.0);
}

#[test]
fn ssd_backend_generates_zero_network_traffic() {
    let mut wb = Workbench::new(0.0002);
    wb.threads = 8;
    let m = wb.run(&ExperimentSpec {
        app: soda::graph::App::Bfs,
        graph: "twitter7",
        backend: BackendKind::Ssd,
        caching: CachingMode::None,
    });
    assert_eq!(m.network_bytes(), 0);
    assert!(m.host.fetched(soda::backend::FetchSource::Ssd) > 0);
}
