//! Integration: all five applications over every backend produce results
//! identical to the in-memory references (functional correctness must be
//! independent of the memory hierarchy underneath).

use soda::coordinator::cluster::Cluster;
use soda::coordinator::config::{BackendKind, CachingMode, ClusterConfig, SodaConfig};
use soda::coordinator::service::SodaService;
use soda::graph::apps::{bc, bfs, cc, pagerank, radii};
use soda::graph::fam_graph::{BuildMode, FamGraph};
use soda::graph::gen::rmat;
use soda::graph::runner::GraphRunner;

fn stage(backend: BackendKind, caching: CachingMode) -> (GraphRunner, FamGraph, soda::graph::CsrGraph) {
    let csr = rmat(1 << 9, 4_000, 0.57, 0.19, 0.19, 99);
    let mut cfg = ClusterConfig::tiny();
    if let BackendKind::Dpu(o) = backend {
        cfg.dpu.opts = o;
    }
    let cluster = Cluster::build(cfg);
    let svc = SodaService::attach(
        &cluster,
        SodaConfig::default().with_backend(backend).with_caching(caching),
    );
    let agent = svc.client_for_footprint("it", csr.vertex_bytes() + csr.edge_bytes());
    let mut r = GraphRunner::new(agent, 8, 0);
    let (g, t) = FamGraph::build(&mut r.agent, 0, &csr, BuildMode::FileBacked);
    r.set_clock(t);
    if caching == CachingMode::Static {
        let now = r.now();
        if let Some(t) = g.pin_vertices_static(&mut r.agent, now) {
            r.set_clock(t);
        }
    }
    (r, g, csr)
}

const BACKENDS: [(BackendKind, CachingMode); 5] = [
    (BackendKind::Ssd, CachingMode::None),
    (BackendKind::MemServer, CachingMode::None),
    (BackendKind::DPU_BASE, CachingMode::None),
    (BackendKind::DPU_OPT, CachingMode::Static),
    (BackendKind::DPU_FULL, CachingMode::Dynamic),
];

#[test]
fn bfs_identical_across_backends() {
    for (backend, caching) in BACKENDS {
        let (mut r, g, csr) = stage(backend, caching);
        let out = bfs::bfs(&mut r, &g, 0);
        assert_eq!(out.levels, bfs::bfs_ref(&csr, 0), "{backend:?}");
    }
}

#[test]
fn pagerank_identical_across_backends() {
    for (backend, caching) in BACKENDS {
        let (mut r, g, csr) = stage(backend, caching);
        let out = pagerank::pagerank(&mut r, &g, 8);
        let want = pagerank::pagerank_ref(&csr, 8);
        for (a, b) in out.ranks.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12, "{backend:?}");
        }
    }
}

#[test]
fn components_identical_across_backends() {
    for (backend, caching) in BACKENDS {
        let (mut r, g, csr) = stage(backend, caching);
        let out = cc::cc(&mut r, &g);
        assert_eq!(out.labels, cc::cc_ref(&csr), "{backend:?}");
    }
}

#[test]
fn bc_identical_across_backends() {
    for (backend, caching) in BACKENDS {
        let (mut r, g, csr) = stage(backend, caching);
        let out = bc::bc(&mut r, &g, 0);
        let want = bc::bc_ref(&csr, 0);
        for (a, b) in out.scores.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9, "{backend:?}");
        }
    }
}

#[test]
fn radii_identical_across_backends() {
    for (backend, caching) in BACKENDS {
        let (mut r, g, csr) = stage(backend, caching);
        let out = radii::radii(&mut r, &g, 5);
        assert_eq!(out.radii, radii::radii_ref(&csr, &out.sources), "{backend:?}");
    }
}

#[test]
fn timing_is_deterministic() {
    // Same seed ⇒ bit-identical virtual runtimes and traffic.
    let run = || {
        let (mut r, g, _csr) = stage(BackendKind::DPU_FULL, CachingMode::Dynamic);
        let t0 = r.now();
        pagerank::pagerank(&mut r, &g, 4);
        (r.now() - t0, r.agent.stats().faults)
    };
    assert_eq!(run(), run());
}
