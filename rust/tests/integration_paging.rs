//! Integration: the full paging path (host agent ⇄ backends ⇄ memory node)
//! with real data movement, across all four backend configurations.

use soda::backend::{DpuStore, MemServerStore, RemoteStore, SsdStore};
use soda::coordinator::cluster::Cluster;
use soda::coordinator::config::{BackendKind, CachingMode, ClusterConfig, SodaConfig};
use soda::coordinator::service::SodaService;
use soda::host::{HostAgent, Placement};
use soda::sim::rng::Rng;

fn agent_on(cluster: &Cluster, store: Box<dyn RemoteStore>, buffer_pages: u64) -> HostAgent {
    let chunk = cluster.config().chunk_bytes;
    HostAgent::new(
        "it",
        store,
        buffer_pages * chunk,
        chunk,
        0.9,
        8,
        8,
        2,
        soda::host::HostTiming::default(),
    )
}

/// Write a pseudorandom pattern through a tiny buffer (forcing evictions),
/// then read it all back and verify byte equality.
fn churn_roundtrip(mut agent: HostAgent, pages: u64) {
    let chunk = agent.chunk_bytes();
    let bytes = pages * chunk;
    let (h, t0) = agent.alloc(0, "obj", bytes, None, Placement::Default);
    let mut rng = Rng::new(7);
    let mut expected = vec![0u8; bytes as usize];
    rng_fill(&mut rng, &mut expected);
    // Write in random-order page-sized strides.
    let mut order: Vec<u64> = (0..pages).collect();
    rng.shuffle(&mut order);
    let mut t = t0;
    for &p in &order {
        let off = p * chunk;
        t = agent.write_bytes(t, 0, h.region, off, &expected[off as usize..(off + chunk) as usize]);
    }
    // Read back in a different random order.
    rng.shuffle(&mut order);
    let mut got = vec![0u8; chunk as usize];
    for &p in &order {
        let off = p * chunk;
        t = agent.read_bytes(t, 0, h.region, off, &mut got);
        assert_eq!(
            &got[..],
            &expected[off as usize..(off + chunk) as usize],
            "page {p} corrupted through eviction/writeback"
        );
    }
    assert!(agent.stats().writebacks > 0, "small buffer must evict dirty pages");
}

#[test]
fn churn_roundtrip_memserver() {
    let cluster = Cluster::build(ClusterConfig::tiny());
    let store = Box::new(MemServerStore::new(cluster.clone()));
    churn_roundtrip(agent_on(&cluster, store, 4), 32);
}

#[test]
fn churn_roundtrip_ssd() {
    let cluster = Cluster::build(ClusterConfig::tiny());
    let store = Box::new(SsdStore::new(cluster.clone()));
    churn_roundtrip(agent_on(&cluster, store, 4), 32);
}

#[test]
fn churn_roundtrip_dpu_full() {
    let mut cfg = ClusterConfig::tiny();
    cfg.dpu.opts = soda::dpu::DpuOpts::FULL;
    let cluster = Cluster::build(cfg);
    let store = Box::new(DpuStore::new(cluster.clone()));
    churn_roundtrip(agent_on(&cluster, store, 4), 32);
}

#[test]
fn churn_roundtrip_dpu_base() {
    let mut cfg = ClusterConfig::tiny();
    cfg.dpu.opts = soda::dpu::DpuOpts::BASE;
    let cluster = Cluster::build(cfg);
    let store = Box::new(DpuStore::new(cluster.clone()));
    churn_roundtrip(agent_on(&cluster, store, 4), 32);
}

#[test]
fn backend_timing_ordering_holds() {
    // A cold page fetch must be fastest from DPU static cache, then
    // memnode, then SSD — the premise of the whole paper.
    let chunk = ClusterConfig::tiny().chunk_bytes;
    let fetch_time = |backend: BackendKind, caching: CachingMode| {
        let cluster = Cluster::build(ClusterConfig::tiny());
        let svc = SodaService::attach(
            &cluster,
            SodaConfig::default().with_backend(backend).with_caching(caching),
        );
        let mut a = svc.client_with_buffer("p", 16 * chunk);
        let (h, t0) =
            a.alloc(0, "x", 8 * chunk, Some(vec![1; (8 * chunk) as usize]), Placement::Static);
        let t1 = if caching == CachingMode::Static {
            a.pin_static(t0, "x").unwrap_or(t0)
        } else {
            t0
        };
        let mut out = vec![0u8; chunk as usize];
        let t2 = a.read_bytes(t1, 0, h.region, 0, &mut out);
        t2 - t1
    };
    let t_ssd = fetch_time(BackendKind::Ssd, CachingMode::None);
    let t_mem = fetch_time(BackendKind::MemServer, CachingMode::None);
    let t_static = fetch_time(BackendKind::DPU_OPT, CachingMode::Static);
    assert!(t_static < t_mem, "DPU static cache ({t_static}) must beat memnode ({t_mem})");
    assert!(t_mem < t_ssd, "memnode ({t_mem}) must beat SSD ({t_ssd})");
}

#[test]
fn dirty_data_survives_dpu_writeback_pipeline() {
    // Write through DPU (host released early), then verify on a second
    // process that maps the region later.
    let mut cfg = ClusterConfig::tiny();
    cfg.dpu.opts = soda::dpu::DpuOpts::FULL;
    let cluster = Cluster::build(cfg);
    let chunk = cluster.config().chunk_bytes;
    let mut writer = agent_on(&cluster, Box::new(DpuStore::new(cluster.clone())), 2);
    let (h, t0) = writer.alloc(0, "shared", 8 * chunk, None, Placement::Default);
    let mut t = t0;
    for p in 0..8u64 {
        let data = vec![(p + 1) as u8; chunk as usize];
        t = writer.write_bytes(t, 0, h.region, p * chunk, &data);
    }
    let t = writer.flush(t);

    let mut reader = agent_on(&cluster, Box::new(DpuStore::new(cluster.clone())), 16);
    let shared = reader.map_shared("shared", h);
    let mut out = vec![0u8; chunk as usize];
    let mut t2 = t + 1_000_000;
    for p in 0..8u64 {
        t2 = reader.read_bytes(t2, 0, shared.region, p * chunk, &mut out);
        assert!(out.iter().all(|&b| b == (p + 1) as u8), "page {p}");
    }
}

#[test]
fn numa_aware_placement_is_faster_end_to_end() {
    let run = |numa_aware: bool| {
        let cluster = Cluster::build(ClusterConfig::tiny());
        let mut scfg = SodaConfig::default().with_backend(BackendKind::MemServer);
        scfg.numa_aware = numa_aware;
        let svc = SodaService::attach(&cluster, scfg);
        let chunk = cluster.config().chunk_bytes;
        let mut a = svc.client_with_buffer("p", 4 * chunk);
        let (h, t0) =
            a.alloc(0, "x", 64 * chunk, Some(vec![1; (64 * chunk) as usize]), Placement::Default);
        let mut out = vec![0u8; chunk as usize];
        let mut t = t0;
        for p in 0..64u64 {
            t = a.read_bytes(t, 0, h.region, p * chunk, &mut out);
        }
        t - t0
    };
    let aware = run(true);
    let naive = run(false);
    assert!(aware < naive, "NUMA-aware placement must be faster ({aware} vs {naive})");
}

fn rng_fill(rng: &mut Rng, buf: &mut [u8]) {
    for chunk in buf.chunks_mut(8) {
        let v = rng.next_u64().to_le_bytes();
        let n = chunk.len();
        chunk.copy_from_slice(&v[..n]);
    }
}
