//! Property test for the pluggable prefetch subsystem: prefetching is a
//! *pure latency optimization* — for random workloads, every policy must
//! leave all observable results identical to prefetch-off:
//!
//! * the bytes every read returns,
//! * the final region contents,
//! * the fault-visible ordering (the page-key sequence of the fault trace),
//! * the host buffer's residency behavior (hits/misses/faults/zero-fills).
//!
//! Only stall/traffic/hit-rate counters may differ. On top of that, the
//! cache table's prefetch accounting must sum exactly:
//! `useful + wasted + still_resident == total prefetched entries`, at any
//! point and under every engine.

use soda::backend::{DpuStore, RemoteStore};
use soda::coordinator::cluster::Cluster;
use soda::coordinator::config::ClusterConfig;
use soda::dpu::{DpuOpts, PrefetchPolicyKind};
use soda::host::{HostAgent, HostTiming, PageKey, PageSpan, Placement};
use soda::sim::rng::Rng;
use soda::util::quickcheck::{forall, Config};

const REGION_PAGES: u64 = 24;

/// One random workload: interleaved span reads/writes over a file-backed
/// and an anonymous region, with hint injections sprinkled in.
#[derive(Clone, Debug)]
struct Case {
    buffer_pages: u64,
    /// (use_anon_region, write, page_offset, byte_len)
    ops: Vec<(bool, bool, u64, usize)>,
    /// After which ops to inject a frontier hint, and its (start, pages).
    hints: Vec<(usize, u64, u64)>,
}

fn gen_case(r: &mut Rng) -> Case {
    let n_ops = 4 + r.index(10);
    let ops = (0..n_ops)
        .map(|_| {
            let anon = r.chance(0.3);
            let write = r.chance(0.3);
            let start = r.below(REGION_PAGES - 1);
            let len = 1 + r.index(((REGION_PAGES - start) * 4096) as usize);
            (anon, write, start, len)
        })
        .collect();
    let hints = (0..r.index(4))
        .map(|_| {
            let start = r.below(REGION_PAGES - 1);
            (r.index(n_ops), start, 1 + r.below(REGION_PAGES - start))
        })
        .collect();
    Case {
        buffer_pages: 3 + r.below(12),
        ops,
        hints,
    }
}

fn make_agent(policy: PrefetchPolicyKind, buffer_pages: u64) -> (HostAgent, Cluster) {
    let mut cfg = ClusterConfig::tiny();
    cfg.dpu.opts = DpuOpts::FULL;
    cfg.dpu.prefetch.policy = policy;
    let cluster = Cluster::build(cfg);
    let chunk = cluster.config().chunk_bytes;
    let store: Box<dyn RemoteStore> = Box::new(DpuStore::new(cluster.clone()));
    let mut agent = HostAgent::new(
        "prop",
        store,
        buffer_pages * chunk,
        chunk,
        0.9,
        4,
        4,
        2,
        HostTiming::default(),
    );
    agent.enable_trace();
    (agent, cluster)
}

struct Observed {
    outputs: Vec<Vec<u8>>,
    trace_pages: Vec<PageKey>,
    faults: u64,
    zero_fills: u64,
    writebacks: u64,
    buf_hits: u64,
    buf_misses: u64,
    final_contents: Vec<Vec<u8>>,
}

fn run_case(case: &Case, policy: PrefetchPolicyKind) -> Observed {
    let (mut a, cluster) = make_agent(policy, case.buffer_pages);
    let chunk = a.chunk_bytes();
    let bytes = REGION_PAGES * chunk;
    let file: Vec<u8> = (0..bytes).map(|i| (i % 241) as u8).collect();
    let (f, t0) = a.alloc(0, "file", bytes, Some(file), Placement::Default);
    let (anon, t1) = a.alloc(t0, "anon", bytes, None, Placement::Default);
    let mut t = t1;
    let mut outputs = Vec::new();
    for (i, &(use_anon, write, start_page, len)) in case.ops.iter().enumerate() {
        let region = if use_anon { anon.region } else { f.region };
        let off = start_page * chunk;
        let len = len.min((bytes - off) as usize).max(1);
        if write {
            let data: Vec<u8> = (0..len).map(|j| ((i * 37 + j) % 239) as u8).collect();
            t = a.write_bytes(t, 0, region, off, &data);
        } else {
            let mut out = vec![0u8; len];
            t = a.read_bytes(t, 0, region, off, &mut out);
            outputs.push(out);
        }
        for &(after, hstart, hpages) in &case.hints {
            if after == i {
                // Hints are advisory: posting one must never change any
                // observable below, listening policy or not.
                a.prefetch_hint(
                    t,
                    &[PageSpan {
                        start: PageKey::new(f.region, hstart),
                        pages: hpages,
                    }],
                );
            }
        }
    }
    let stats = a.stats();
    let buf = a.buffer_stats();
    // Cache-table accounting must sum exactly at any observation point.
    let cs = cluster.dpu_cache_stats();
    assert_eq!(
        cs.insertions,
        cs.prefetch_useful + cs.prefetch_wasted + cs.resident_untouched,
        "{policy:?}: useful+wasted+resident must equal total prefetched entries"
    );
    // Full read-back of both regions (far in the future so everything in
    // flight has landed).
    let mut final_contents = Vec::new();
    let mut t_end = t + 1_000_000_000;
    for region in [f.region, anon.region] {
        let mut all = vec![0u8; bytes as usize];
        t_end = a.read_bytes(t_end, 0, region, 0, &mut all);
        final_contents.push(all);
    }
    Observed {
        outputs,
        trace_pages: a.take_trace().into_iter().map(|(_, k)| k).collect(),
        faults: stats.faults,
        zero_fills: stats.zero_fills,
        writebacks: stats.writebacks,
        buf_hits: buf.hits,
        buf_misses: buf.misses,
        final_contents,
    }
}

#[test]
fn prefetching_never_changes_observable_results() {
    forall(
        Config { cases: 30, seed: 0x9F37C4 },
        gen_case,
        |case| {
            let base = run_case(case, PrefetchPolicyKind::Off);
            for policy in PrefetchPolicyKind::ALL {
                let got = run_case(case, policy);
                if got.outputs != base.outputs {
                    return Err(format!("{policy:?}: read bytes diverged from prefetch-off"));
                }
                if got.final_contents != base.final_contents {
                    return Err(format!("{policy:?}: final region contents diverged"));
                }
                if got.trace_pages != base.trace_pages {
                    return Err(format!(
                        "{policy:?}: fault-visible ordering diverged ({} vs {} faults)",
                        got.trace_pages.len(),
                        base.trace_pages.len()
                    ));
                }
                if (got.faults, got.zero_fills, got.writebacks)
                    != (base.faults, base.zero_fills, base.writebacks)
                {
                    return Err(format!("{policy:?}: host fault counters diverged"));
                }
                if (got.buf_hits, got.buf_misses) != (base.buf_hits, base.buf_misses) {
                    return Err(format!("{policy:?}: buffer hit/miss counts diverged"));
                }
            }
            Ok(())
        },
    );
}

/// The adaptive wrapped forms go through the same equivalence check (they
/// share the throttling code path, which truncates issue lists and must
/// never touch request handling).
#[test]
fn adaptive_wrapped_engines_are_observably_equivalent_too() {
    use soda::dpu::AdaptiveBase;
    forall(
        Config { cases: 10, seed: 0xADA7 },
        gen_case,
        |case| {
            let base = run_case(case, PrefetchPolicyKind::Off);
            for policy in [
                PrefetchPolicyKind::Adaptive(AdaptiveBase::Strided),
                PrefetchPolicyKind::Adaptive(AdaptiveBase::GraphHint),
            ] {
                let got = run_case(case, policy);
                if got.outputs != base.outputs || got.final_contents != base.final_contents {
                    return Err(format!("{policy:?}: data diverged from prefetch-off"));
                }
                if got.trace_pages != base.trace_pages {
                    return Err(format!("{policy:?}: fault ordering diverged"));
                }
            }
            Ok(())
        },
    );
}

/// Graph-level determinism: the same BFS run twice on identical clusters
/// (graph-hint policy, hints flowing) must produce bit-identical metrics —
/// no wall-clock or RNG leakage into plans.
#[test]
fn graph_hint_runs_are_deterministic() {
    use soda::coordinator::config::{BackendKind, CachingMode, PrefetchOverride};
    use soda::graph::App;
    use soda::workload::{ExperimentSpec, Workbench};
    let run = || {
        let mut wb = Workbench::new(0.0001);
        wb.threads = 8;
        wb.prefetch = Some(PrefetchOverride {
            policy: Some(PrefetchPolicyKind::GraphHint),
            ..PrefetchOverride::default()
        });
        let m = wb.run(&ExperimentSpec {
            app: App::Bfs,
            graph: "friendster",
            backend: BackendKind::DPU_FULL,
            caching: CachingMode::Dynamic,
        });
        (
            m.elapsed_ns,
            m.host.faults,
            m.host.stall_ns,
            m.host.hints_sent,
            m.dpu.hint_entries,
            m.dpu.dynamic_hits,
            m.network_bytes(),
            m.dpu_cache.prefetch_useful,
            m.dpu_cache.prefetch_wasted_bytes,
        )
    };
    assert_eq!(run(), run(), "identical runs must be bit-identical");
}
