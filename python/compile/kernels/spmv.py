"""Layer 1 — Pallas blocked-ELL SpMV kernel.

The graph-analytics hot spot (PageRank's gather-accumulate over in-edges)
re-thought for TPU per the hardware-adaptation mandate:

* Ligra's irregular CSR edge scan becomes a **fixed-width ELLPACK tile**:
  each vertex row holds exactly K column slots, padded with -1. Every grid
  step then works on a dense ``(TILE_ROWS, K)`` rectangle — the shape a
  systolic/vector unit wants, instead of the warp-per-row dynamic loop a
  GPU would use.
* The HBM→VMEM schedule is explicit in the ``BlockSpec``s: each grid step
  stages one row-tile of the column-index matrix plus the full contribution
  vector in VMEM (the vector plays the role of the GPU's shared-memory
  staging buffer; at N = 16 Ki f32 it is 64 KiB — far under VMEM budget).
* The per-row reduction is a vectorized masked gather + sum along K, which
  XLA maps onto the VPU; there is no per-edge branching.

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Numerics are
verified against the pure-jnp oracle in ``ref.py`` by the pytest suite.

VMEM footprint per grid step (see DESIGN.md §Perf):
    cols tile  TILE_ROWS × K × 4 B
  + contrib    N × 4 B
  + out tile   TILE_ROWS × 4 B
Defaults (TILE_ROWS=512, K=16, N=16384): 32 KiB + 64 KiB + 2 KiB ≈ 98 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile height; rows per grid step.
DEFAULT_TILE_ROWS = 512


def _ell_spmv_kernel(contrib_ref, cols_ref, out_ref):
    """One row-tile: masked gather of contributions + reduce along K."""
    contrib = contrib_ref[...]  # (N,) in VMEM
    cols = cols_ref[...]  # (T, K) in VMEM
    mask = cols >= 0
    safe = jnp.where(mask, cols, 0)
    gathered = contrib[safe]  # vectorized take
    out_ref[...] = jnp.where(mask, gathered, 0.0).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def ell_spmv(contrib, cols, *, tile_rows=DEFAULT_TILE_ROWS):
    """sums[i] = Σ_k contrib[cols[i, k]] over valid (non-negative) slots.

    contrib: f32[N]; cols: i32[R, K] with -1 padding; R % tile_rows == 0.
    Returns f32[R].
    """
    rows, k = cols.shape
    n = contrib.shape[0]
    if rows % tile_rows != 0:
        raise ValueError(f"rows {rows} not divisible by tile_rows {tile_rows}")
    grid = (rows // tile_rows,)
    return pl.pallas_call(
        _ell_spmv_kernel,
        grid=grid,
        in_specs=[
            # The whole contribution vector is resident in VMEM each step.
            pl.BlockSpec((n,), lambda i: (0,)),
            # One row-tile of the ELL column matrix per step.
            pl.BlockSpec((tile_rows, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), contrib.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(contrib, cols)


def vmem_bytes(n, tile_rows, k, dtype_bytes=4):
    """Estimated VMEM footprint of one grid step (for DESIGN.md §Perf)."""
    return n * dtype_bytes + tile_rows * k * 4 + tile_rows * dtype_bytes
