"""Pure-jnp oracle for the Layer-1 kernels.

This is the CORE correctness signal: the Pallas kernel and the full L2
PageRank superstep are asserted allclose against these references by the
pytest suite (including hypothesis sweeps over shapes and values).
"""

import jax.numpy as jnp

DAMPING = 0.85


def ell_spmv_ref(contrib, cols):
    """sums[i] = sum over valid slots k of contrib[cols[i, k]]."""
    mask = cols >= 0
    safe = jnp.where(mask, cols, 0)
    gathered = contrib[safe]
    return jnp.where(mask, gathered, 0.0).sum(axis=1)


def pagerank_step_ref(ranks, inv_deg, cols, spill_sums, damping=DAMPING):
    """One PageRank iteration over an ELL adjacency (+ host spill sums).

    ranks:     f32[N] current ranks
    inv_deg:   f32[N] 1/out-degree (0 for isolated vertices)
    cols:      i32[N, K] in-neighbor ids, -1 padded
    spill_sums:f32[N] contributions of neighbors beyond slot K
               (computed host-side for heavy rows; zeros otherwise)

    Returns (new_ranks f32[N], l1_delta f32[]).
    """
    n = ranks.shape[0]
    contrib = ranks * inv_deg
    sums = ell_spmv_ref(contrib, cols) + spill_sums
    new_ranks = (1.0 - damping) / n + damping * sums
    delta = jnp.abs(new_ranks - ranks).sum()
    return new_ranks, delta
