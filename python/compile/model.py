"""Layer 2 — the JAX compute graph: one PageRank superstep.

Calls the Layer-1 Pallas kernel (`kernels.spmv.ell_spmv`) for the
gather-accumulate hot spot and keeps the cheap elementwise tail (rank
update, L1 convergence delta) in plain jnp so XLA fuses it into the same
module. Lowered once by `aot.py`; never imported at runtime — the Rust
coordinator executes the AOT artifact through PJRT.

The `spill_sums` input makes the fixed-width ELL format exact on power-law
graphs: rows wider than K spill their remaining neighbors to the host
(which sums them with the same contrib values) and the artifact adds them
back in. Zero spill ⇒ pure-kernel path.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.spmv import DEFAULT_TILE_ROWS, ell_spmv

DAMPING = 0.85


@functools.partial(jax.jit, static_argnames=("tile_rows", "damping"))
def pagerank_step(
    ranks,
    inv_deg,
    cols,
    spill_sums,
    *,
    tile_rows=DEFAULT_TILE_ROWS,
    damping=DAMPING,
):
    """One PageRank iteration. Shapes: ranks/inv_deg/spill_sums f32[N],
    cols i32[N, K]. Returns (new_ranks f32[N], l1_delta f32[])."""
    n = ranks.shape[0]
    contrib = ranks * inv_deg
    sums = ell_spmv(contrib, cols, tile_rows=tile_rows) + spill_sums
    new_ranks = (1.0 - damping) / n + damping * sums
    delta = jnp.abs(new_ranks - ranks).sum()
    return new_ranks, delta


def example_args(n, k):
    """ShapeDtypeStructs for AOT lowering at a given (N, K)."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n,), f32),      # ranks
        jax.ShapeDtypeStruct((n,), f32),      # inv_deg
        jax.ShapeDtypeStruct((n, k), jnp.int32),  # cols
        jax.ShapeDtypeStruct((n,), f32),      # spill_sums
    )
