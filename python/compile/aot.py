"""AOT lowering: JAX → HLO **text** artifacts for the Rust runtime.

HLO text — not ``.serialize()`` protos — is the interchange format: jax
≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``
so the Rust side unwraps one tuple.

Usage:  cd python && python -m compile.aot --out ../artifacts
Emits:  pagerank_step_{N}x{K}.hlo.txt per variant + manifest.json.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import example_args, pagerank_step

# (N, K, tile_rows) variants compiled by default: a test-sized module and
# the example-sized module used by examples/xla_pagerank.rs.
DEFAULT_VARIANTS = [
    (1024, 8, 256),
    (4096, 16, 512),
]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(n: int, k: int, tile_rows: int) -> str:
    fn = lambda r, d, c, s: pagerank_step(r, d, c, s, tile_rows=tile_rows)
    lowered = jax.jit(fn).lower(*example_args(n, k))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--variant",
        action="append",
        default=None,
        metavar="NxK[xTILE]",
        help="extra variant, e.g. 8192x32x512 (repeatable)",
    )
    args = ap.parse_args()

    variants = list(DEFAULT_VARIANTS)
    for spec in args.variant or []:
        parts = [int(x) for x in spec.lower().split("x")]
        if len(parts) == 2:
            parts.append(min(512, parts[0]))
        n, k, tile = parts
        variants.append((n, k, tile))

    os.makedirs(args.out, exist_ok=True)
    manifest = []
    for n, k, tile in variants:
        if n % tile != 0:
            raise SystemExit(f"N={n} not divisible by tile_rows={tile}")
        name = f"pagerank_step_{n}x{k}.hlo.txt"
        path = os.path.join(args.out, name)
        text = lower_variant(n, k, tile)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            {
                "file": name,
                "n": n,
                "k": k,
                "tile_rows": tile,
                "inputs": ["ranks f32[n]", "inv_deg f32[n]", "cols i32[n,k]", "spill_sums f32[n]"],
                "outputs": ["new_ranks f32[n]", "l1_delta f32[]"],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest}, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
