"""AOT path: lowering must produce well-formed HLO text that the Rust
runtime's `HloModuleProto::from_text_file` can parse (format checks here;
the full load-and-execute round trip is covered by `cargo test
integration_runtime` after `make artifacts`)."""

import numpy as np

from compile.aot import lower_variant
from compile.kernels.ref import pagerank_step_ref
from compile.model import pagerank_step

import jax.numpy as jnp


def test_lowering_emits_hlo_text():
    text = lower_variant(256, 4, 64)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Tuple return: jax lowers (new_ranks, delta) into a 2-tuple root.
    assert "tuple" in text
    # All four parameters present.
    for i in range(4):
        assert f"parameter({i})" in text, f"missing parameter {i}"


def test_lowered_module_matches_eager():
    """The numbers the artifact computes == the eager jax numbers."""
    n, k, tile = 256, 4, 64
    rng = np.random.default_rng(7)
    ranks = rng.random(n).astype(np.float32)
    inv_deg = rng.random(n).astype(np.float32)
    cols = rng.integers(-1, n, size=(n, k), dtype=np.int32)
    spill = np.zeros(n, dtype=np.float32)
    got = pagerank_step(
        jnp.asarray(ranks), jnp.asarray(inv_deg), jnp.asarray(cols),
        jnp.asarray(spill), tile_rows=tile,
    )
    want = pagerank_step_ref(
        jnp.asarray(ranks), jnp.asarray(inv_deg), jnp.asarray(cols), jnp.asarray(spill)
    )
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), rtol=1e-6)


def test_no_serialized_protos():
    """Guard against regressing to .serialize() (xla_extension 0.5.1
    rejects jax>=0.5's 64-bit instruction ids): text must be ASCII HLO,
    not protobuf bytes."""
    text = lower_variant(256, 4, 64)
    assert text.isprintable() or "\n" in text
    assert text.lstrip().startswith("HloModule")
