"""L1 correctness: Pallas ELL-SpMV kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and values; fixed cases pin the paper-relevant
configurations (power-law-ish rows, empty rows, full rows).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ell_spmv_ref
from compile.kernels.spmv import ell_spmv, vmem_bytes


def random_ell(rng, n, rows, k, fill):
    """Random ELL column matrix with `fill` fraction of valid slots."""
    cols = rng.integers(0, n, size=(rows, k), dtype=np.int32)
    mask = rng.random((rows, k)) < fill
    return np.where(mask, cols, -1).astype(np.int32)


def assert_kernel_matches_ref(contrib, cols, tile_rows):
    got = ell_spmv(jnp.asarray(contrib), jnp.asarray(cols), tile_rows=tile_rows)
    want = ell_spmv_ref(jnp.asarray(contrib), jnp.asarray(cols))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_basic_small():
    contrib = np.array([1.0, 2.0, 4.0, 8.0], dtype=np.float32)
    cols = np.array([[1, 2], [0, -1], [-1, -1], [3, 3]], dtype=np.int32)
    got = ell_spmv(jnp.asarray(contrib), jnp.asarray(cols), tile_rows=2)
    np.testing.assert_allclose(np.asarray(got), [6.0, 1.0, 0.0, 16.0])


def test_all_padding_rows_are_zero():
    contrib = np.ones(8, dtype=np.float32)
    cols = np.full((4, 3), -1, dtype=np.int32)
    got = ell_spmv(jnp.asarray(contrib), jnp.asarray(cols), tile_rows=4)
    assert np.all(np.asarray(got) == 0.0)


def test_full_rows_sum_everything():
    n, k = 16, 16
    contrib = np.arange(n, dtype=np.float32)
    cols = np.tile(np.arange(k, dtype=np.int32), (n, 1))
    got = ell_spmv(jnp.asarray(contrib), jnp.asarray(cols), tile_rows=8)
    np.testing.assert_allclose(np.asarray(got), np.full(n, contrib.sum()))


def test_rows_must_divide_tile():
    with pytest.raises(ValueError):
        ell_spmv(jnp.ones(4), jnp.zeros((6, 2), jnp.int32), tile_rows=4)


@pytest.mark.parametrize("rows,k,tile", [(8, 1, 4), (32, 7, 8), (64, 16, 64), (128, 3, 16)])
def test_shapes_grid(rows, k, tile):
    rng = np.random.default_rng(rows * 31 + k)
    n = 64
    contrib = rng.standard_normal(n).astype(np.float32)
    cols = random_ell(rng, n, rows, k, 0.6)
    assert_kernel_matches_ref(contrib, cols, tile)


@settings(max_examples=25, deadline=None)
@given(
    rows_pow=st.integers(2, 6),
    k=st.integers(1, 12),
    fill=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(rows_pow, k, fill, seed):
    rows = 1 << rows_pow
    tile = max(1, rows // 4)
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 200))
    contrib = rng.standard_normal(n).astype(np.float32)
    cols = random_ell(rng, n, rows, k, fill)
    assert_kernel_matches_ref(contrib, cols, tile)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_power_law_rows(seed):
    """Degree-skewed rows: a few near-full, most near-empty (graph shape)."""
    rng = np.random.default_rng(seed)
    n, rows, k = 128, 64, 16
    contrib = rng.standard_normal(n).astype(np.float32)
    fills = rng.pareto(1.5, size=rows).clip(0, 1)
    cols = rng.integers(0, n, size=(rows, k), dtype=np.int32)
    mask = rng.random((rows, k)) < fills[:, None]
    cols = np.where(mask, cols, -1).astype(np.int32)
    assert_kernel_matches_ref(contrib, cols, 16)


def test_dtype_bfloat16_matches_loosely():
    rng = np.random.default_rng(0)
    n, rows, k = 64, 32, 8
    contrib = rng.standard_normal(n).astype(np.float32)
    cols = random_ell(rng, n, rows, k, 0.5)
    got = ell_spmv(jnp.asarray(contrib, jnp.bfloat16), jnp.asarray(cols), tile_rows=8)
    want = ell_spmv_ref(jnp.asarray(contrib), jnp.asarray(cols))
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=5e-2, atol=5e-2
    )


def test_vmem_estimate_within_budget():
    # Default config must sit far below a TPU core's ~16 MiB VMEM.
    assert vmem_bytes(16384, 512, 16) < 4 * 1024 * 1024


def test_kernel_is_jittable_and_stable():
    rng = np.random.default_rng(3)
    contrib = rng.standard_normal(32).astype(np.float32)
    cols = random_ell(rng, 32, 16, 4, 0.7)
    a = ell_spmv(jnp.asarray(contrib), jnp.asarray(cols), tile_rows=4)
    b = ell_spmv(jnp.asarray(contrib), jnp.asarray(cols), tile_rows=4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _ = jax.jit(lambda c, x: ell_spmv(c, x, tile_rows=4))(
        jnp.asarray(contrib), jnp.asarray(cols)
    )
