"""L2 correctness: the PageRank superstep graph vs the oracle, plus
fixed-point sanity on real (small) graph structures."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import pagerank_step_ref
from compile.model import example_args, pagerank_step


def graph_to_ell(neighbors, n, k):
    """Split adjacency into ELL (first k) + spill lists (rest)."""
    cols = np.full((n, k), -1, dtype=np.int32)
    spill = [[] for _ in range(n)]
    for v, nbrs in enumerate(neighbors):
        head, tail = nbrs[:k], nbrs[k:]
        cols[v, : len(head)] = head
        spill[v] = tail
    return cols, spill


def run_step(ranks, inv_deg, cols, spill_sums, tile):
    got = pagerank_step(
        jnp.asarray(ranks), jnp.asarray(inv_deg), jnp.asarray(cols),
        jnp.asarray(spill_sums), tile_rows=tile,
    )
    want = pagerank_step_ref(
        jnp.asarray(ranks), jnp.asarray(inv_deg), jnp.asarray(cols),
        jnp.asarray(spill_sums),
    )
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), rtol=1e-6)
    np.testing.assert_allclose(float(got[1]), float(want[1]), rtol=1e-5, atol=1e-7)
    return np.asarray(got[0])


def test_step_matches_ref_random():
    rng = np.random.default_rng(1)
    n, k = 64, 8
    ranks = rng.random(n).astype(np.float32)
    ranks /= ranks.sum()
    deg = rng.integers(1, 20, n)
    inv_deg = (1.0 / deg).astype(np.float32)
    cols = rng.integers(0, n, size=(n, k), dtype=np.int32)
    cols[rng.random((n, k)) < 0.3] = -1
    spill = rng.random(n).astype(np.float32) * 0.01
    run_step(ranks, inv_deg, cols, spill, 16)


@settings(max_examples=15, deadline=None)
@given(n_pow=st.integers(3, 7), k=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_step_hypothesis(n_pow, k, seed):
    n = 1 << n_pow
    rng = np.random.default_rng(seed)
    ranks = rng.random(n).astype(np.float32)
    inv_deg = rng.random(n).astype(np.float32)
    cols = rng.integers(-1, n, size=(n, k), dtype=np.int32)
    spill = np.zeros(n, dtype=np.float32)
    run_step(ranks, inv_deg, cols, spill, max(1, n // 4))


def test_star_graph_fixpoint_shape():
    """Star: center rank must dominate after a few steps (undirected)."""
    n, k = 8, 8
    neighbors = [[i for i in range(1, n)]] + [[0]] * (n - 1)
    cols, spill_lists = graph_to_ell(neighbors, n, k)
    assert all(len(s) == 0 for s in spill_lists)
    deg = np.array([len(x) for x in neighbors], dtype=np.float32)
    inv_deg = 1.0 / deg
    ranks = np.full(n, 1.0 / n, dtype=np.float32)
    for _ in range(10):
        ranks = run_step(ranks, inv_deg, cols, np.zeros(n, np.float32), 4)
    assert ranks[0] > ranks[1] * 2
    np.testing.assert_allclose(ranks.sum(), 1.0, rtol=1e-4)


def test_spill_path_is_exact():
    """Rows wider than K: ELL + host spill must equal the full sum."""
    n, k = 16, 2
    rng = np.random.default_rng(2)
    neighbors = [list(rng.integers(0, n, rng.integers(0, 6))) for _ in range(n)]
    cols, spill_lists = graph_to_ell(neighbors, n, k)
    ranks = rng.random(n).astype(np.float32)
    deg = np.array([max(1, len(x)) for x in neighbors], dtype=np.float32)
    inv_deg = (1.0 / deg).astype(np.float32)
    contrib = ranks * inv_deg
    spill_sums = np.array(
        [sum(contrib[u] for u in tail) for tail in spill_lists], dtype=np.float32
    )
    got = pagerank_step(
        jnp.asarray(ranks), jnp.asarray(inv_deg), jnp.asarray(cols),
        jnp.asarray(spill_sums), tile_rows=4,
    )
    # Dense reference over the full adjacency (no ELL, no spill).
    full = np.zeros(n, dtype=np.float64)
    for v, nbrs in enumerate(neighbors):
        full[v] = sum(contrib[u] for u in nbrs)
    want = (1.0 - 0.85) / n + 0.85 * full
    np.testing.assert_allclose(np.asarray(got[0]), want.astype(np.float32), rtol=1e-5)


def test_example_args_shapes():
    args = example_args(1024, 8)
    assert args[0].shape == (1024,)
    assert args[2].shape == (1024, 8)
    assert str(args[2].dtype) == "int32"
