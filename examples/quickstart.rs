//! Quickstart: allocate FAM-backed memory objects, read/write through the
//! SODA runtime, and inspect what the memory hierarchy did.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use soda::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. A simulated cluster: host + off-path DPU + memory node, wired by
    //    the calibrated fabric (100 GbE RoCE, PCIe switch, 4 NUMA nodes).
    let cluster = Cluster::build(ClusterConfig::default());

    // 2. Attach SODA with the full optimization set (aggregation + async
    //    forwarding + dynamic caching) and get a process client.
    let svc = SodaService::attach(&cluster, SodaConfig::default());
    let mut proc0 = svc.client_with_buffer("rank0", 8 << 20);

    // 3. SODA_alloc: an anonymous FAM object (zero pages on first touch)…
    let (anon, t0) = proc0.alloc(0, "scratch", 4 << 20, None, Placement::Default);
    println!("allocated {} MB anonymous FAM object (region {})", anon.bytes >> 20, anon.region);

    // …and a file-backed object the memory node pre-loads server-side.
    let payload: Vec<u8> = (0..1 << 20).map(|i| (i % 251) as u8).collect();
    let (file_obj, t1) = proc0.alloc(t0, "dataset", payload.len() as u64, Some(payload), Placement::Static);
    println!("allocated {} MB file-backed FAM object (region {})", file_obj.bytes >> 20, file_obj.region);

    // 4. Use them like ordinary memory: write, then read back.
    let t2 = proc0.write_bytes(t1, 0, anon.region, 12_345, b"hello fabric-attached memory");
    let mut back = [0u8; 28];
    let t3 = proc0.read_bytes(t2, 0, anon.region, 12_345, &mut back);
    assert_eq!(&back, b"hello fabric-attached memory");
    println!("write + read back OK: {:?}", std::str::from_utf8(&back)?);

    // 5. Read through the file-backed object (faults chunks on demand,
    //    forwarded by the DPU agent).
    let mut window = vec![0u8; 256];
    let t4 = proc0.read_bytes(t3, 0, file_obj.region, 500_000, &mut window);
    assert!(window.iter().enumerate().all(|(i, &b)| b == ((500_000 + i) % 251) as u8));
    println!("file-backed window verified ({} bytes at offset 500000)", window.len());

    // 6. Pin the dataset into the DPU's static cache: later faults are
    //    served from DPU DRAM with zero on-demand network traffic.
    let t5 = proc0.pin_static(t4, "dataset").expect("DPU backend supports pinning");
    let t5b = proc0.invalidate_buffer(t5);
    let od_before = cluster.network_stats().on_demand_bytes();
    let mut probe = vec![0u8; 4096];
    let t6 = proc0.read_bytes(t5b, 0, file_obj.region, 0, &mut probe);
    let od_after = cluster.network_stats().on_demand_bytes();
    println!(
        "after static pin: refetch added {} on-demand network bytes (expected 0)",
        od_after - od_before
    );

    // 7. Metrics: everything the runtime observed, in virtual time.
    let m = svc.collect("quickstart", t6, &proc0);
    println!("\n{m}");
    println!("(virtual time elapsed: {:.3} ms)", soda::sim::ns_to_secs(t6) * 1e3);
    Ok(())
}
