//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//! 1. **L3** generates a scaled friendster graph, moves it into FAM through
//!    the SODA runtime (DPU-opt backend, static vertex caching) and runs
//!    the Ligra-style PageRank, reporting the paper's headline metrics
//!    (runtime vs the SSD baseline, network traffic, DPU hit rates).
//! 2. **L2/L1** — the same PageRank math runs through the AOT-compiled
//!    Pallas blocked-ELL SpMV artifact on the PJRT CPU client, with heavy
//!    rows spilled to the host (exact hybrid), proving the artifacts the
//!    build produced actually compute the right numbers from Rust.
//! 3. The two rank vectors are cross-validated.
//!
//! ```sh
//! make artifacts && cargo run --release --example xla_pagerank
//! ```

use soda::coordinator::config::{BackendKind, CachingMode};
use soda::graph::apps::pagerank::{pagerank, pagerank_ref};
use soda::graph::apps::App;
use soda::runtime::{cpu_client, to_ell, Manifest, PagerankEngine};
use soda::workload::{ExperimentSpec, Workbench};

const ITERS: u32 = 20;

fn main() -> anyhow::Result<()> {
    // ---- Layer 3: SODA + Ligra on the simulated cluster ----------------
    let scale = 0.00006; // ~4000 vertices: matches the 4096x16 artifact
    let mut wb = Workbench::new(scale);
    let csr = wb.graph("friendster").clone();
    println!(
        "graph: friendster @ {scale} — |V| = {}, |E| = {}",
        csr.n(),
        csr.m()
    );

    let ssd = wb.run(&ExperimentSpec {
        app: App::PageRank,
        graph: "friendster",
        backend: BackendKind::Ssd,
        caching: CachingMode::None,
    });
    let soda_run = wb.run(&ExperimentSpec {
        app: App::PageRank,
        graph: "friendster",
        backend: BackendKind::DPU_OPT,
        caching: CachingMode::Static,
    });
    println!("\n== L3: SODA vs node-local SSD (virtual time) ==");
    println!("  ssd      : {:.3} ms", ssd.elapsed_secs() * 1e3);
    println!(
        "  soda     : {:.3} ms  → speedup {:.2}x",
        soda_run.elapsed_secs() * 1e3,
        ssd.elapsed_ns as f64 / soda_run.elapsed_ns as f64
    );
    println!(
        "  (at this micro scale the whole graph fits the SSD page cache, so the\n            SSD baseline is near in-memory; run `soda figures fig6 --scale 0.001`\n            for the paper-scale comparison where SODA wins up to ~3x)"
    );
    println!(
        "  traffic  : {:.2} MB ({:.1}% background), dpu static serves: {}",
        soda_run.network_bytes() as f64 / 1e6,
        soda_run.network.background_fraction() * 100.0,
        soda_run.dpu.static_serves,
    );

    // ---- Layers 2+1: the AOT Pallas/JAX artifact through PJRT ----------
    println!("\n== L1/L2: AOT PageRank superstep on PJRT ==");
    let manifest = Manifest::load("artifacts")
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;
    let spec = manifest
        .best_for(csr.n(), 16)
        .ok_or_else(|| anyhow::anyhow!("no artifact ≥ {} rows; add a variant", csr.n()))?;
    let client = cpu_client()?;
    let engine = PagerankEngine::load(&client, &manifest.dir, spec)?;
    println!(
        "  artifact: {} (n={}, k={}) on {}",
        spec.file,
        spec.n,
        spec.k,
        client.platform_name()
    );

    // Pad the graph into the artifact's fixed ELL shape, spilling heavy rows.
    let n_pad = engine.n;
    let neighbors: Vec<Vec<u32>> = (0..csr.n() as u32).map(|v| csr.neighbors(v).to_vec()).collect();
    let (cols, spill_lists) = to_ell(&neighbors, n_pad, engine.k);
    let spilled_edges: usize = spill_lists.iter().map(|s| s.len()).sum();
    println!(
        "  ELL: {} rows x {} slots, {} edges spilled to host ({:.1}%)",
        n_pad,
        engine.k,
        spilled_edges,
        100.0 * spilled_edges as f64 / csr.m() as f64
    );

    let mut inv_deg = vec![0.0f32; n_pad];
    for v in 0..csr.n() {
        inv_deg[v] = 1.0 / csr.degree(v as u32).max(1) as f32;
    }
    let mut ranks = vec![0.0f32; n_pad];
    for r in ranks.iter_mut().take(csr.n()) {
        *r = 1.0 / csr.n() as f32;
    }
    let mut spill = vec![0.0f32; n_pad];
    let t_wall = std::time::Instant::now();
    let mut last_delta = 0.0;
    for _ in 0..ITERS {
        // Host computes the spilled contributions (hybrid ELL+spill = exact).
        let contrib: Vec<f32> = ranks.iter().zip(&inv_deg).map(|(r, d)| r * d).collect();
        for (v, tail) in spill_lists.iter().enumerate() {
            spill[v] = tail.iter().map(|&u| contrib[u as usize]).sum();
        }
        let (next, delta) = engine.step(&ranks, &inv_deg, &cols, &spill)?;
        ranks = next;
        last_delta = delta;
    }
    println!(
        "  {} iterations in {:.1} ms wallclock, final L1 delta = {:.3e}",
        ITERS,
        t_wall.elapsed().as_secs_f64() * 1e3,
        last_delta
    );

    // ---- Cross-validation: L1/L2 vs L3 vs reference ---------------------
    // Padded rows have no edges and deg clamp 1 — compare real vertices.
    // The artifact's base term uses n_pad, so rescale to compare shapes.
    let reference = pagerank_ref(&csr, ITERS);
    let top_ref = argmax(&reference[..csr.n()]);
    let top_xla = argmax(&ranks[..csr.n()].iter().map(|&x| x as f64).collect::<Vec<_>>());
    println!("\n== cross-validation ==");
    println!("  top-ranked vertex: reference = {top_ref}, xla = {top_xla}");
    anyhow::ensure!(top_ref == top_xla, "rank orderings disagree");
    let corr = rank_correlation(&reference[..csr.n()], &ranks[..csr.n()]);
    println!("  rank correlation (ref vs xla): {corr:.6}");
    anyhow::ensure!(corr > 0.999, "correlation too low: {corr}");

    // And the FAM run (same algorithm through the paging stack).
    let (mut runner, g) = {
        // quick FAM re-run for rank comparison
        let mut wb2 = Workbench::new(scale);
        let _ = wb2.graph("friendster");
        let cluster = soda::coordinator::cluster::Cluster::build(Workbench::scaled_cluster_config());
        let svc = soda::coordinator::service::SodaService::attach(
            &cluster,
            soda::coordinator::config::SodaConfig::default()
                .with_backend(BackendKind::MemServer),
        );
        let agent = svc.client_for_footprint("p0", csr.vertex_bytes() + csr.edge_bytes());
        let mut r = soda::graph::runner::GraphRunner::new(agent, 8, 0);
        let (g, t) = soda::graph::fam_graph::FamGraph::build(
            &mut r.agent,
            0,
            &csr,
            soda::graph::fam_graph::BuildMode::FileBacked,
        );
        r.set_clock(t);
        (r, g)
    };
    let fam = pagerank(&mut runner, &g, ITERS);
    let max_err = reference
        .iter()
        .zip(&fam.ranks)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("  max |ref - fam| = {max_err:.3e}");
    anyhow::ensure!(max_err < 1e-12, "FAM run diverged from reference");
    println!("\nall three layers agree — end-to-end stack verified ✓");
    Ok(())
}

fn argmax(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

/// Pearson correlation between two rank vectors.
fn rank_correlation(a: &[f64], b: &[f32]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
    let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
    for (x, y) in a.iter().zip(b) {
        let (dx, dy) = (x - ma, *y as f64 - mb);
        cov += dx * dy;
        va += dx * dx;
        vb += dy * dy;
    }
    cov / (va.sqrt() * vb.sqrt())
}
