//! Multi-process DPU sharing — the Fig 8 scenario.
//!
//! Each application co-runs with a background BFS on the same compute
//! node; both processes share the node's single DPU agent ("this DPU
//! sharing is fully transparent from the client's perspective", §III) and
//! its static cache. Reports execution time and network-traffic reduction
//! of SODA vs. the no-offloading MemServer baseline.
//!
//! ```sh
//! cargo run --release --example multi_tenant -- [scale]
//! ```

use soda::coordinator::config::{BackendKind, CachingMode};
use soda::graph::apps::App;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.0005);
    let mut wb = Workbench::new(scale);
    println!("co-running each app with a background BFS on friendster @ scale {scale}\n");
    println!(
        "{:<12}{:>12}{:>12}{:>13}{:>13}{:>11}",
        "app", "mem (ms)", "soda (ms)", "mem MB", "soda MB", "Δtraffic"
    );
    for app in App::ALL {
        let (mem, _) = wb.run_with_background_bfs(&ExperimentSpec {
            app,
            graph: "friendster",
            backend: BackendKind::MemServer,
            caching: CachingMode::None,
        });
        let (soda, replayed) = wb.run_with_background_bfs(&ExperimentSpec {
            app,
            graph: "friendster",
            backend: BackendKind::DPU_OPT,
            caching: CachingMode::Static,
        });
        println!(
            "{:<12}{:>12.2}{:>12.2}{:>13.2}{:>13.2}{:>10.1}%  (bg trace: {} faults)",
            app.name(),
            mem.elapsed_secs() * 1e3,
            soda.elapsed_secs() * 1e3,
            mem.network_bytes() as f64 / 1e6,
            soda.network_bytes() as f64 / 1e6,
            soda.traffic_delta_over(&mem) * 100.0,
            replayed,
        );
    }
    println!("\n(the paper reports traffic reductions of up to 25% in this scenario)");
}
