//! Graph analytics over FAM — the paper's case study in miniature.
//!
//! Runs the five Ligra applications on a scaled friendster over all four
//! system configurations (local SSD, direct memory server, DPU base, DPU
//! opt) and prints the comparison table Fig 6/7 are built from.
//!
//! ```sh
//! cargo run --release --example graph_analytics -- [scale]
//! ```

use soda::coordinator::config::{BackendKind, CachingMode};
use soda::graph::apps::App;
use soda::workload::{ExperimentSpec, Workbench};

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.0005);
    let mut wb = Workbench::new(scale);
    println!(
        "friendster @ scale {scale}: |V| = {}, |E| = {} (E/V = {:.1})\n",
        wb.graph("friendster").n(),
        wb.graph("friendster").m(),
        wb.graph("friendster").avg_degree()
    );
    let configs = [
        ("local SSD", BackendKind::Ssd, CachingMode::None),
        ("memserver", BackendKind::MemServer, CachingMode::None),
        ("dpu-base", BackendKind::DPU_BASE, CachingMode::None),
        ("dpu-opt+static", BackendKind::DPU_OPT, CachingMode::Static),
    ];
    println!(
        "{:<12}{:>14}{:>14}{:>14}{:>16}",
        "app", configs[0].0, configs[1].0, configs[2].0, configs[3].0
    );
    for app in App::ALL {
        let mut line = format!("{:<12}", app.name());
        let mut times = Vec::new();
        for (_, backend, caching) in configs {
            let m = wb.run(&ExperimentSpec {
                app,
                graph: "friendster",
                backend,
                caching,
            });
            times.push(m.elapsed_secs());
        }
        for (i, t) in times.iter().enumerate() {
            if i == 0 {
                line.push_str(&format!("{:>12.4}s ", t));
            } else {
                line.push_str(&format!("{:>8.4}s {:>4.1}x", t, times[0] / t));
            }
        }
        println!("{line}");
    }
    println!("\n(speedups relative to node-local SSD — the paper reports up to 7.9x)");
}
